//! Cluster-wide tracing on the **virtual clock** (DESIGN.md §2.11).
//!
//! The scheduler, shuffle planner and failure domain already compute
//! everything a trace needs — attempt launch/end times, locality tiers,
//! per-reducer fetch seconds, node deaths — they just throw the structure
//! away after folding it into counters. The [`TraceSink`] keeps it: the
//! engine hands over each finished job's plans ([`JobTrace`]) and the sink
//! lays them out on a run-global virtual timeline as typed [`Span`]s
//! (run → phase → job → setup / attempt → dispatch / read / compute /
//! write / fetch) plus instant events for deaths and blacklists.
//!
//! Determinism: every span timestamp derives from `SchedulePlan` /
//! `FetchPlan` virtual times, which are pure functions of the cost model
//! and the seeded fault stream. Master-side compute (`absorb_master`) is
//! wall-measured and therefore **excluded** — the trace's makespan is the
//! sum of job virtual times, self-consistent with its own critical path.
//!
//! On top of the span tree: [`export`] (Chrome trace-event JSON, one track
//! per slave slot, Perfetto-loadable), [`critical`] (critical-path,
//! straggler and reducer-skew analysis) and [`report`] (the unified
//! RunReport JSON).

pub mod critical;
pub mod export;
pub mod json;
pub mod report;

use std::sync::Mutex;

use crate::cluster::NetworkModel;
use crate::mapreduce::shuffle::fetch::ReducerFetch;
use crate::scheduler::{Locality, SchedulePlan, TaskSpec};

/// Track id of the driver/master lane (job, setup and barrier spans).
/// Slave slots occupy tracks `1 + global_slot`.
pub const DRIVER_TRACK: usize = 0;

/// Tolerance when checking that modeled IO components fit inside an
/// attempt span (matches the scheduler's EPS scale).
const EPS: f64 = 1e-9;

/// Span category: what level of the job → attempt → IO hierarchy a span
/// models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// The whole run (one per trace, track 0).
    Run,
    /// One pipeline phase (similarity / eigenvectors / kmeans).
    Phase,
    /// One MapReduce job (named `pipeline:stage` by the dataflow planner).
    Job,
    /// Job setup overhead (`job_overhead(m)`).
    Setup,
    /// One task attempt on a slot track.
    Attempt,
    /// Attempt child: tracker dispatch latency.
    Dispatch,
    /// Attempt child: locality-tiered input read.
    Read,
    /// Attempt child: modeled compute (the residual of the attempt).
    Compute,
    /// Attempt child: output write/spill.
    Write,
    /// The job-level shuffle barrier (slowest reducer's fetch phase).
    FetchBarrier,
    /// Reduce-attempt child: that reducer's own segment fetches.
    Fetch,
}

impl SpanKind {
    /// Stable lowercase name (the trace-event `cat` field).
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Run => "run",
            SpanKind::Phase => "phase",
            SpanKind::Job => "job",
            SpanKind::Setup => "setup",
            SpanKind::Attempt => "attempt",
            SpanKind::Dispatch => "dispatch",
            SpanKind::Read => "read",
            SpanKind::Compute => "compute",
            SpanKind::Write => "write",
            SpanKind::FetchBarrier => "fetch-barrier",
            SpanKind::Fetch => "fetch",
        }
    }
}

/// One argument attached to a span or instant event.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    /// Unsigned integer argument.
    U64(u64),
    /// String argument.
    Str(String),
}

/// One closed span on the virtual clock.
#[derive(Debug, Clone)]
pub struct Span {
    /// Category (nesting level).
    pub kind: SpanKind,
    /// Display name (job name, `map t3`, `fetch`, ...).
    pub name: String,
    /// Track: [`DRIVER_TRACK`] or `1 + global_slot`.
    pub track: usize,
    /// Virtual start, seconds since run start.
    pub start_s: f64,
    /// Virtual end, seconds since run start.
    pub end_s: f64,
    /// Typed arguments (task id, slave, locality, ...).
    pub args: Vec<(&'static str, ArgValue)>,
}

/// An instant event (node death, slave blacklist) pinned to the driver
/// track.
#[derive(Debug, Clone)]
pub struct InstantEvent {
    /// Event name (`node-death`, `slave-blacklisted`).
    pub name: &'static str,
    /// Virtual time, seconds since run start.
    pub time_s: f64,
    /// Typed arguments (the slave involved).
    pub args: Vec<(&'static str, ArgValue)>,
}

/// Modeled IO components of one attempt, priced exactly like the
/// scheduler's `duration()`: dispatch + locality-tiered read + write. The
/// compute slice is the attempt's residual, so children always tile the
/// attempt span.
#[derive(Debug, Clone, Copy, Default)]
pub struct AttemptIo {
    /// Tracker dispatch latency.
    pub dispatch_s: f64,
    /// Input read at the attempt's locality tier.
    pub read_s: f64,
    /// Output write.
    pub write_s: f64,
    /// Bytes the read slice moves (the task's declared input).
    pub read_bytes: u64,
    /// Bytes the write slice moves (the task's declared output).
    pub write_bytes: u64,
}

/// One schedule plan plus the per-attempt IO decomposition the span
/// builder needs (parallel to `plan.attempts`).
#[derive(Debug, Clone)]
pub struct PlanTrace {
    /// The scheduler's plan (cloned; the engine keeps the original).
    pub plan: SchedulePlan,
    /// `io[i]` decomposes `plan.attempts[i]`.
    pub io: Vec<AttemptIo>,
}

/// Build a [`PlanTrace`] from a plan and the task specs it scheduled,
/// re-deriving each attempt's IO slices from the cost model (the same
/// formulas the scheduler's `duration()` charged).
pub fn plan_trace(
    plan: &SchedulePlan,
    specs: &[TaskSpec],
    model: &NetworkModel,
) -> PlanTrace {
    let io = plan
        .attempts
        .iter()
        .map(|a| {
            let (input, output) = specs
                .get(a.task)
                .map(|s| (s.cost.input_bytes, s.cost.output_bytes))
                .unwrap_or((0, 0));
            AttemptIo {
                dispatch_s: model.task_dispatch_s,
                read_s: model.read_time_at(input, a.locality),
                write_s: model.write_time(output),
                read_bytes: input,
                write_bytes: output,
            }
        })
        .collect();
    PlanTrace { plan: plan.clone(), io }
}

/// Shuffle-fetch inputs for one reduce job's trace.
#[derive(Debug, Clone)]
pub struct FetchTrace {
    /// The slowest reducer's fetch seconds (the barrier the makespan pays).
    pub fetch_s: f64,
    /// Per-reducer fetch detail, indexed by reduce task id.
    pub reducers: Vec<ReducerFetch>,
}

/// Everything the engine knows about one finished job, in the order the
/// job's virtual timeline lays it out: overhead, map plan, lost-output
/// rerun plans, fetch barrier, reduce plan.
#[derive(Debug, Clone)]
pub struct JobTrace {
    /// Job name (`pipeline:stage` for dataflow jobs).
    pub name: String,
    /// Job setup overhead seconds.
    pub overhead_s: f64,
    /// The job's total virtual seconds (what `JobStats` reports).
    pub virtual_time_s: f64,
    /// The map phase plan.
    pub map: PlanTrace,
    /// Lost-output re-execution plans, in the order they ran.
    pub reruns: Vec<PlanTrace>,
    /// The fetch barrier (reduce jobs only).
    pub fetch: Option<FetchTrace>,
    /// The reduce phase plan (reduce jobs only).
    pub reduce: Option<PlanTrace>,
    /// Shuffle bytes each map task spilled (Σ its partition segments);
    /// empty for map-only jobs. Telemetry's spill-size histogram input.
    pub spill_bytes: Vec<u64>,
}

/// One segment of a job's critical path. Segments are laid end to end:
/// their seconds sum to the job's `virtual_time_s` (and, across jobs, to
/// the run makespan).
#[derive(Debug, Clone)]
pub struct Segment {
    /// Segment kind: `setup`, `map-wait`, `map`, `map-rerun-wait`,
    /// `map-rerun`, `shuffle-fetch`, `reduce-wait`, `reduce`.
    pub kind: String,
    /// Which attempt carried the segment (`t3@slave1`), empty for
    /// barriers.
    pub detail: String,
    /// Virtual seconds.
    pub seconds: f64,
}

/// Analysis record of one job: its critical-path decomposition plus the
/// per-attempt durations the straggler report aggregates.
#[derive(Debug, Clone)]
pub struct JobRec {
    /// Job name.
    pub name: String,
    /// Phase open when the job ran (empty outside any phase).
    pub phase: String,
    /// Virtual start, seconds since run start.
    pub start_s: f64,
    /// The job's virtual seconds.
    pub virtual_s: f64,
    /// Critical-path segments (sum == `virtual_s`).
    pub segments: Vec<Segment>,
    /// Winning map-attempt durations (reruns included).
    pub map_durations: Vec<f64>,
    /// Winning reduce-attempt durations.
    pub reduce_durations: Vec<f64>,
    /// Bytes fetched per reducer (reduce jobs only; skew input).
    pub reducer_bytes: Vec<u64>,
    /// Per winning attempt (map, rerun and reduce plans alike): virtual
    /// seconds it waited between phase start and dispatch — the
    /// queue-wait histogram input.
    pub queue_waits: Vec<f64>,
    /// Shuffle bytes each map task spilled (empty for map-only jobs).
    pub spill_bytes: Vec<u64>,
}

/// One phase window on the run timeline.
#[derive(Debug, Clone)]
pub struct PhaseRec {
    /// Phase name.
    pub name: String,
    /// Virtual start.
    pub start_s: f64,
    /// Virtual end (the run cursor when the phase closed).
    pub end_s: f64,
}

/// Immutable snapshot of a trace: everything the exporter and analyzers
/// consume.
#[derive(Debug, Clone)]
pub struct TraceData {
    /// Slave count the sink was enabled with.
    pub slaves: usize,
    /// Slots per slave (track layout).
    pub slots_per_slave: usize,
    /// Run makespan: the virtual cursor after the last recorded job.
    pub makespan_s: f64,
    /// Phase windows, in order.
    pub phases: Vec<PhaseRec>,
    /// Analysis records, one per job, in execution order.
    pub jobs: Vec<JobRec>,
    /// Job/attempt/IO spans, in emission order.
    pub spans: Vec<Span>,
    /// Death/blacklist instants.
    pub instants: Vec<InstantEvent>,
}

#[derive(Debug, Default)]
struct TraceState {
    slaves: usize,
    slots_per_slave: usize,
    cursor_s: f64,
    open: Option<usize>,
    phases: Vec<PhaseRec>,
    jobs: Vec<JobRec>,
    spans: Vec<Span>,
    instants: Vec<InstantEvent>,
}

/// The shared trace sink. Lives on the [`crate::cluster::Cluster`] behind
/// an `Arc` (like the failure domain), so every clone of the cluster —
/// driver, planner, engine — records into the same timeline. Disabled by
/// default: a `None` inner state makes [`TraceSink::record_job`] a no-op,
/// so untraced runs pay one mutex probe per job and nothing else.
#[derive(Debug, Default)]
pub struct TraceSink {
    inner: Mutex<Option<TraceState>>,
}

impl TraceSink {
    /// Turn tracing on, declaring the slot-track layout. Resets any
    /// previously recorded trace.
    pub fn enable(&self, slaves: usize, slots_per_slave: usize) {
        let mut g = self.inner.lock().unwrap();
        *g = Some(TraceState {
            slaves,
            slots_per_slave: slots_per_slave.max(1),
            ..TraceState::default()
        });
    }

    /// Is the sink recording?
    pub fn enabled(&self) -> bool {
        self.inner.lock().unwrap().is_some()
    }

    /// Open a phase window at the current cursor (closing any open one).
    pub fn begin_phase(&self, name: &str) {
        let mut g = self.inner.lock().unwrap();
        let Some(st) = g.as_mut() else { return };
        if let Some(i) = st.open.take() {
            st.phases[i].end_s = st.cursor_s;
        }
        st.phases.push(PhaseRec {
            name: name.to_string(),
            start_s: st.cursor_s,
            end_s: f64::INFINITY,
        });
        st.open = Some(st.phases.len() - 1);
    }

    /// Close the open phase window at the current cursor.
    pub fn end_phase(&self) {
        let mut g = self.inner.lock().unwrap();
        let Some(st) = g.as_mut() else { return };
        if let Some(i) = st.open.take() {
            st.phases[i].end_s = st.cursor_s;
        }
    }

    /// Record one finished job: lay its plans out at the run cursor, emit
    /// spans and instants, build the critical-path segments, and advance
    /// the cursor by the job's virtual time. No-op while disabled.
    pub fn record_job(&self, job: JobTrace) {
        let mut g = self.inner.lock().unwrap();
        let Some(st) = g.as_mut() else { return };
        st.record_job(job);
    }

    /// Snapshot the recorded trace (`None` while disabled). Open phases
    /// are closed at the current cursor in the copy.
    pub fn snapshot(&self) -> Option<TraceData> {
        let g = self.inner.lock().unwrap();
        let st = g.as_ref()?;
        let mut phases = st.phases.clone();
        for p in &mut phases {
            if !p.end_s.is_finite() {
                p.end_s = st.cursor_s;
            }
        }
        Some(TraceData {
            slaves: st.slaves,
            slots_per_slave: st.slots_per_slave,
            makespan_s: st.cursor_s,
            phases,
            jobs: st.jobs.clone(),
            spans: st.spans.clone(),
            instants: st.instants.clone(),
        })
    }
}

impl TraceState {
    fn record_job(&mut self, job: JobTrace) {
        let t0 = self.cursor_s;
        let job_end = t0 + job.virtual_time_s;
        let phase = self
            .open
            .map(|i| self.phases[i].name.clone())
            .unwrap_or_default();

        self.spans.push(Span {
            kind: SpanKind::Job,
            name: job.name.clone(),
            track: DRIVER_TRACK,
            start_s: t0,
            end_s: job_end,
            args: vec![("phase", ArgValue::Str(phase.clone()))],
        });
        self.spans.push(Span {
            kind: SpanKind::Setup,
            name: "setup".to_string(),
            track: DRIVER_TRACK,
            start_s: t0,
            end_s: (t0 + job.overhead_s).min(job_end),
            args: Vec::new(),
        });

        let mut segments = vec![Segment {
            kind: "setup".to_string(),
            detail: String::new(),
            seconds: job.overhead_s,
        }];

        let map_off = t0 + job.overhead_s;
        self.emit_plan(&job.map, map_off, job_end, "map", None);
        push_plan_segments(&mut segments, &job.map.plan, "map");
        let mut map_durations = winning_durations(&job.map.plan);
        let mut queue_waits = winning_waits(&job.map.plan);

        let mut off = map_off + job.map.plan.makespan_s;
        for rerun in &job.reruns {
            self.emit_plan(rerun, off, job_end, "map-rerun", None);
            push_plan_segments(&mut segments, &rerun.plan, "map-rerun");
            map_durations.extend(winning_durations(&rerun.plan));
            queue_waits.extend(winning_waits(&rerun.plan));
            off += rerun.plan.makespan_s;
        }

        let mut reduce_durations = Vec::new();
        let mut reducer_bytes = Vec::new();
        if let Some(reduce) = &job.reduce {
            let fetch_s = job.fetch.as_ref().map_or(0.0, |f| f.fetch_s);
            self.spans.push(Span {
                kind: SpanKind::FetchBarrier,
                name: "shuffle-fetch".to_string(),
                track: DRIVER_TRACK,
                start_s: off,
                end_s: (off + fetch_s).min(job_end),
                args: job
                    .fetch
                    .as_ref()
                    .map(|f| {
                        vec![(
                            "fetches",
                            ArgValue::U64(
                                f.reducers.iter().map(|r| r.fetches).sum(),
                            ),
                        )]
                    })
                    .unwrap_or_default(),
            });
            segments.push(Segment {
                kind: "shuffle-fetch".to_string(),
                detail: String::new(),
                seconds: fetch_s,
            });
            let reduce_off = off + fetch_s;
            self.emit_plan(reduce, reduce_off, job_end, "reduce", job.fetch.as_ref());
            push_plan_segments(&mut segments, &reduce.plan, "reduce");
            reduce_durations = winning_durations(&reduce.plan);
            queue_waits.extend(winning_waits(&reduce.plan));
            reducer_bytes = job
                .fetch
                .as_ref()
                .map(|f| f.reducers.iter().map(|r| r.bytes).collect())
                .unwrap_or_default();
        }

        self.jobs.push(JobRec {
            name: job.name,
            phase,
            start_s: t0,
            virtual_s: job.virtual_time_s,
            segments,
            map_durations,
            reduce_durations,
            reducer_bytes,
            queue_waits,
            spill_bytes: job.spill_bytes,
        });
        self.cursor_s = job_end;
    }

    /// Emit one plan's attempt spans at offset `off`, clamped to the job
    /// span. Winning reduce attempts widen backward by their reducer's own
    /// fetch seconds (always ≤ the barrier, so they stay inside the job)
    /// and carry a leading `fetch` child.
    fn emit_plan(
        &mut self,
        pt: &PlanTrace,
        off: f64,
        clamp_end: f64,
        label: &str,
        fetch: Option<&FetchTrace>,
    ) {
        for (i, a) in pt.plan.attempts.iter().enumerate() {
            let fetch_r = if a.won {
                fetch
                    .and_then(|f| f.reducers.get(a.task))
                    .map_or(0.0, |r| r.fetch_s)
            } else {
                0.0
            };
            let body_start = off + a.start_s;
            let start = body_start - fetch_r;
            let end = (off + a.end_s).min(clamp_end);
            if end < start {
                continue;
            }
            let track = 1 + a.slot;
            self.spans.push(Span {
                kind: SpanKind::Attempt,
                name: format!("{label} t{}", a.task),
                track,
                start_s: start,
                end_s: end,
                args: vec![
                    ("task", ArgValue::U64(a.task as u64)),
                    ("slave", ArgValue::U64(a.slave as u64)),
                    ("locality", ArgValue::Str(locality_str(a.locality).into())),
                    ("speculative", ArgValue::U64(a.speculative as u64)),
                    ("won", ArgValue::U64(a.won as u64)),
                ],
            });
            if !a.won {
                continue;
            }
            if fetch_r > 0.0 {
                self.spans.push(Span {
                    kind: SpanKind::Fetch,
                    name: "fetch".to_string(),
                    track,
                    start_s: start,
                    end_s: body_start.min(end),
                    args: Vec::new(),
                });
            }
            let io = pt.io.get(i).copied().unwrap_or_default();
            let compute = (end - body_start) - io.dispatch_s - io.read_s - io.write_s;
            // A clamped attempt (death past the makespan) may not fit its
            // modeled IO; skip the children rather than emit overlaps.
            if compute < -EPS {
                continue;
            }
            let compute = compute.max(0.0);
            let mut t = body_start;
            for (kind, name, dur, bytes) in [
                (SpanKind::Dispatch, "dispatch", io.dispatch_s, 0),
                (SpanKind::Read, "read", io.read_s, io.read_bytes),
                (SpanKind::Compute, "compute", compute, 0),
                (SpanKind::Write, "write", io.write_s, io.write_bytes),
            ] {
                if dur <= 0.0 {
                    continue;
                }
                // Read/write children carry the bytes they move so the
                // telemetry layer can gauge DFS bytes in flight.
                let args = if bytes > 0 {
                    vec![("bytes", ArgValue::U64(bytes))]
                } else {
                    Vec::new()
                };
                self.spans.push(Span {
                    kind,
                    name: name.to_string(),
                    track,
                    start_s: t,
                    end_s: (t + dur).min(end),
                    args,
                });
                t += dur;
            }
        }
        for &(slave, t) in &pt.plan.death_events {
            self.instants.push(InstantEvent {
                name: "node-death",
                time_s: off + t,
                args: vec![("slave", ArgValue::U64(slave as u64))],
            });
        }
        for &(slave, t) in &pt.plan.blacklisted {
            self.instants.push(InstantEvent {
                name: "slave-blacklisted",
                time_s: off + t,
                args: vec![("slave", ArgValue::U64(slave as u64))],
            });
        }
    }
}

/// Stable lowercase rendering of a locality tier.
pub fn locality_str(l: Locality) -> &'static str {
    match l {
        Locality::NodeLocal => "node-local",
        Locality::RackLocal => "rack-local",
        Locality::OffRack => "off-rack",
    }
}

fn winning_durations(plan: &SchedulePlan) -> Vec<f64> {
    plan.attempts
        .iter()
        .filter(|a| a.won)
        .map(|a| a.end_s - a.start_s)
        .collect()
}

/// Per winning attempt: plan-relative dispatch time — how long the task
/// waited in the queue (every task is ready at plan start).
fn winning_waits(plan: &SchedulePlan) -> Vec<f64> {
    plan.attempts.iter().filter(|a| a.won).map(|a| a.start_s).collect()
}

/// Append the wait/run critical segments of one plan: the plan's makespan
/// is exactly its slowest winner's end time, so `wait(start) + run(dur)`
/// sums to `makespan_s`. Plans with no winners (nothing scheduled)
/// contribute nothing — and have zero makespan.
fn push_plan_segments(segments: &mut Vec<Segment>, plan: &SchedulePlan, label: &str) {
    let Some(crit) = plan
        .attempts
        .iter()
        .filter(|a| a.won)
        .max_by(|a, b| a.end_s.total_cmp(&b.end_s))
    else {
        return;
    };
    segments.push(Segment {
        kind: format!("{label}-wait"),
        detail: String::new(),
        seconds: crit.start_s,
    });
    segments.push(Segment {
        kind: label.to_string(),
        detail: format!("t{}@slave{}", crit.task, crit.slave),
        seconds: crit.end_s - crit.start_s,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::Attempt;

    fn attempt(task: usize, slave: usize, slot: usize, s: f64, e: f64, won: bool) -> Attempt {
        Attempt {
            task,
            slave,
            slot,
            start_s: s,
            end_s: e,
            locality: Locality::NodeLocal,
            speculative: false,
            won,
        }
    }

    fn plan_of(attempts: Vec<Attempt>) -> SchedulePlan {
        let makespan = attempts
            .iter()
            .filter(|a| a.won)
            .map(|a| a.end_s)
            .fold(0.0, f64::max);
        SchedulePlan { makespan_s: makespan, attempts, ..SchedulePlan::default() }
    }

    fn io_for(plan: &SchedulePlan, dispatch: f64) -> Vec<AttemptIo> {
        plan.attempts
            .iter()
            .map(|_| AttemptIo { dispatch_s: dispatch, ..AttemptIo::default() })
            .collect()
    }

    fn map_only_job(name: &str, overhead: f64, plan: SchedulePlan) -> JobTrace {
        let io = io_for(&plan, 0.5);
        JobTrace {
            name: name.to_string(),
            overhead_s: overhead,
            virtual_time_s: overhead + plan.makespan_s,
            map: PlanTrace { plan, io },
            reruns: Vec::new(),
            fetch: None,
            reduce: None,
            spill_bytes: Vec::new(),
        }
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let sink = TraceSink::default();
        assert!(!sink.enabled());
        sink.record_job(map_only_job("j", 1.0, plan_of(vec![])));
        assert!(sink.snapshot().is_none());
    }

    #[test]
    fn jobs_advance_the_cursor_and_segments_sum_to_virtual_time() {
        let sink = TraceSink::default();
        sink.enable(2, 2);
        sink.begin_phase("similarity");
        let plan = plan_of(vec![
            attempt(0, 0, 0, 1.0, 5.0, true),
            attempt(1, 1, 2, 1.0, 7.0, true),
        ]);
        sink.record_job(map_only_job("a", 2.0, plan));
        let plan = plan_of(vec![attempt(0, 0, 1, 0.5, 3.0, true)]);
        sink.record_job(map_only_job("b", 2.0, plan));
        sink.end_phase();
        let data = sink.snapshot().unwrap();
        assert_eq!(data.jobs.len(), 2);
        assert!((data.makespan_s - (9.0 + 5.0)).abs() < 1e-12);
        assert_eq!(data.phases.len(), 1);
        assert_eq!(data.phases[0].name, "similarity");
        assert!((data.phases[0].end_s - data.makespan_s).abs() < 1e-12);
        for job in &data.jobs {
            let sum: f64 = job.segments.iter().map(|s| s.seconds).sum();
            assert!(
                (sum - job.virtual_s).abs() < 1e-9,
                "{}: {sum} vs {}",
                job.name,
                job.virtual_s
            );
            assert_eq!(job.phase, "similarity");
        }
        // Second job starts where the first ended.
        assert!((data.jobs[1].start_s - 9.0).abs() < 1e-12);
    }

    #[test]
    fn attempt_spans_nest_inside_their_job() {
        let sink = TraceSink::default();
        sink.enable(2, 2);
        let plan = plan_of(vec![
            attempt(0, 0, 0, 1.0, 5.0, true),
            attempt(0, 1, 2, 2.0, 5.0, false), // killed loser
        ]);
        sink.record_job(map_only_job("j", 2.0, plan));
        let data = sink.snapshot().unwrap();
        let job = data
            .spans
            .iter()
            .find(|s| s.kind == SpanKind::Job)
            .expect("job span");
        for s in &data.spans {
            assert!(
                s.start_s >= job.start_s - 1e-12 && s.end_s <= job.end_s + 1e-12,
                "{:?} escapes the job span",
                s
            );
        }
        // Attempts sit on slot tracks, children tile the winner.
        let attempts: Vec<_> =
            data.spans.iter().filter(|s| s.kind == SpanKind::Attempt).collect();
        assert_eq!(attempts.len(), 2);
        assert_eq!(attempts[0].track, 1);
        assert_eq!(attempts[1].track, 3);
        let children: Vec<_> = data
            .spans
            .iter()
            .filter(|s| matches!(s.kind, SpanKind::Dispatch | SpanKind::Compute))
            .collect();
        assert!(!children.is_empty(), "winner must have IO children");
        for c in &children {
            assert!(c.start_s >= attempts[0].start_s - 1e-12);
            assert!(c.end_s <= attempts[0].end_s + 1e-12);
        }
    }

    #[test]
    fn reduce_winners_widen_backward_with_a_fetch_child() {
        let sink = TraceSink::default();
        sink.enable(1, 2);
        let map = plan_of(vec![attempt(0, 0, 0, 0.0, 2.0, true)]);
        let reduce = plan_of(vec![attempt(0, 0, 1, 1.0, 4.0, true)]);
        let map_io = io_for(&map, 0.5);
        let reduce_io = io_for(&reduce, 0.5);
        let fetch = FetchTrace {
            fetch_s: 3.0,
            reducers: vec![ReducerFetch { fetch_s: 2.0, fetches: 1, bytes: 100 }],
        };
        let job = JobTrace {
            name: "r".to_string(),
            overhead_s: 1.0,
            virtual_time_s: 1.0 + 2.0 + 3.0 + 4.0,
            map: PlanTrace { plan: map, io: map_io },
            reruns: Vec::new(),
            fetch: Some(fetch),
            reduce: Some(PlanTrace { plan: reduce, io: reduce_io }),
            spill_bytes: vec![100],
        };
        sink.record_job(job);
        let data = sink.snapshot().unwrap();
        let sum: f64 = data.jobs[0].segments.iter().map(|s| s.seconds).sum();
        assert!((sum - 10.0).abs() < 1e-9, "{sum}");
        let red = data
            .spans
            .iter()
            .find(|s| s.kind == SpanKind::Attempt && s.name.starts_with("reduce"))
            .unwrap();
        // Barrier ends at 1+2+3=6; attempt body starts at 6+1=7, widened
        // to 5 by its own 2s fetch.
        assert!((red.start_s - 5.0).abs() < 1e-12, "{}", red.start_s);
        let fetch_span = data
            .spans
            .iter()
            .find(|s| s.kind == SpanKind::Fetch)
            .expect("fetch child");
        assert!(fetch_span.start_s >= red.start_s - 1e-12);
        assert!(fetch_span.end_s <= red.end_s + 1e-12);
        assert!((fetch_span.end_s - 7.0).abs() < 1e-12);
        assert_eq!(data.jobs[0].reducer_bytes, vec![100]);
    }

    #[test]
    fn death_events_become_instants() {
        let sink = TraceSink::default();
        sink.enable(2, 1);
        let mut plan = plan_of(vec![attempt(0, 0, 0, 0.0, 2.0, true)]);
        plan.death_events.push((1, 1.5));
        plan.blacklisted.push((1, 1.5));
        sink.record_job(map_only_job("j", 1.0, plan));
        let data = sink.snapshot().unwrap();
        assert_eq!(data.instants.len(), 2);
        assert_eq!(data.instants[0].name, "node-death");
        assert!((data.instants[0].time_s - 2.5).abs() < 1e-12, "offset by setup");
        assert_eq!(data.instants[1].name, "slave-blacklisted");
    }
}
