//! Critical-path, straggler and reducer-skew analysis over a recorded
//! trace.
//!
//! Jobs run serially on the driver's virtual timeline, so the run's
//! critical path is the concatenation of each job's critical segments
//! (setup → slowest-map wait/run → rerun waves → fetch barrier →
//! slowest-reduce wait/run). By construction the segments of one job sum
//! to its virtual time, and across jobs to the run makespan — the
//! analyzer's total is an identity check, not an estimate.

use super::{Segment, TraceData};

/// Seconds attributed to one phase on the critical path.
#[derive(Debug, Clone)]
pub struct PhaseShare {
    /// Phase name ("" for jobs recorded outside any phase).
    pub name: String,
    /// Critical-path seconds inside the phase.
    pub seconds: f64,
}

/// Seconds attributed to one segment kind on the critical path.
#[derive(Debug, Clone)]
pub struct KindShare {
    /// Segment kind (`setup`, `map`, `shuffle-fetch`, ...).
    pub kind: String,
    /// Critical-path seconds of that kind.
    pub seconds: f64,
}

/// One of the top-k critical segments.
#[derive(Debug, Clone)]
pub struct TopSegment {
    /// Phase the segment's job ran in.
    pub phase: String,
    /// Job name.
    pub job: String,
    /// Segment kind.
    pub kind: String,
    /// Attempt detail (`t3@slave1`), empty for barriers.
    pub detail: String,
    /// Virtual seconds.
    pub seconds: f64,
}

/// The run's critical path, decomposed three ways.
#[derive(Debug, Clone)]
pub struct CriticalPath {
    /// Sum of every critical segment (== run makespan up to f64 noise).
    pub total_s: f64,
    /// Jobs on the path.
    pub jobs: usize,
    /// Per-phase attribution, in phase order.
    pub by_phase: Vec<PhaseShare>,
    /// Per-kind attribution, descending by seconds.
    pub by_kind: Vec<KindShare>,
    /// The k largest segments, descending.
    pub top: Vec<TopSegment>,
}

/// Walk the per-job segment chains and attribute the makespan.
pub fn analyze(data: &TraceData, top_k: usize) -> CriticalPath {
    let mut total = 0.0f64;
    let mut by_phase: Vec<PhaseShare> = Vec::new();
    let mut by_kind: Vec<KindShare> = Vec::new();
    let mut top: Vec<TopSegment> = Vec::new();
    for job in &data.jobs {
        for seg in &job.segments {
            total += seg.seconds;
            match by_phase.iter_mut().find(|p| p.name == job.phase) {
                Some(p) => p.seconds += seg.seconds,
                None => by_phase.push(PhaseShare {
                    name: job.phase.clone(),
                    seconds: seg.seconds,
                }),
            }
            match by_kind.iter_mut().find(|k| k.kind == seg.kind) {
                Some(k) => k.seconds += seg.seconds,
                None => by_kind
                    .push(KindShare { kind: seg.kind.clone(), seconds: seg.seconds }),
            }
            top.push(TopSegment {
                phase: job.phase.clone(),
                job: job.name.clone(),
                kind: seg.kind.clone(),
                detail: seg.detail.clone(),
                seconds: seg.seconds,
            });
        }
    }
    by_kind.sort_by(|a, b| b.seconds.total_cmp(&a.seconds).then(a.kind.cmp(&b.kind)));
    top.sort_by(|a, b| b.seconds.total_cmp(&a.seconds));
    top.truncate(top_k);
    CriticalPath { total_s: total, jobs: data.jobs.len(), by_phase, by_kind, top }
}

impl CriticalPath {
    /// Human-readable report. The first line is stable and grep-able:
    /// `critical path: <total>s over <jobs> jobs ...`.
    pub fn render(&self, makespan_s: f64) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "critical path: {:.6}s over {} jobs (run makespan {:.6}s)\n",
            self.total_s, self.jobs, makespan_s
        ));
        let pct = |s: f64| {
            if self.total_s > 0.0 {
                100.0 * s / self.total_s
            } else {
                0.0
            }
        };
        let phases: Vec<String> = self
            .by_phase
            .iter()
            .map(|p| {
                let name = if p.name.is_empty() { "(none)" } else { &p.name };
                format!("{name} {:.1}% ({:.1}s)", pct(p.seconds), p.seconds)
            })
            .collect();
        out.push_str(&format!("  by phase: {}\n", phases.join(", ")));
        let kinds: Vec<String> = self
            .by_kind
            .iter()
            .map(|k| format!("{} {:.1}%", k.kind, pct(k.seconds)))
            .collect();
        out.push_str(&format!("  by kind:  {}\n", kinds.join(", ")));
        for (i, t) in self.top.iter().enumerate() {
            let detail =
                if t.detail.is_empty() { String::new() } else { format!(" ({})", t.detail) };
            let phase = if t.phase.is_empty() { "(none)" } else { &t.phase };
            out.push_str(&format!(
                "  top {:>2}. [{phase}] {} {} {:.2}s{detail}\n",
                i + 1,
                t.job,
                t.kind,
                t.seconds,
            ));
        }
        out
    }
}

/// Per-phase straggler statistics over winning-attempt durations (map and
/// reduce attempts pooled — reruns included).
#[derive(Debug, Clone)]
pub struct StragglerStats {
    /// Phase name ("" outside any phase).
    pub phase: String,
    /// Winning attempts in the phase.
    pub attempts: usize,
    /// Median attempt duration.
    pub p50_s: f64,
    /// 95th-percentile attempt duration.
    pub p95_s: f64,
    /// Slowest attempt duration.
    pub max_s: f64,
}

/// Nearest-rank percentile over an unsorted sample (q in [0,1]).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Aggregate attempt durations per phase.
pub fn stragglers(data: &TraceData) -> Vec<StragglerStats> {
    let mut phases: Vec<(String, Vec<f64>)> = Vec::new();
    for job in &data.jobs {
        let bucket = match phases.iter_mut().find(|(name, _)| *name == job.phase) {
            Some((_, v)) => v,
            None => {
                phases.push((job.phase.clone(), Vec::new()));
                &mut phases.last_mut().unwrap().1
            }
        };
        bucket.extend_from_slice(&job.map_durations);
        bucket.extend_from_slice(&job.reduce_durations);
    }
    phases
        .into_iter()
        .map(|(phase, mut durs)| {
            durs.sort_by(f64::total_cmp);
            StragglerStats {
                phase,
                attempts: durs.len(),
                p50_s: percentile(&durs, 0.50),
                p95_s: percentile(&durs, 0.95),
                max_s: durs.last().copied().unwrap_or(0.0),
            }
        })
        .collect()
}

/// Shuffle-bytes skew across one reduce job's reducers.
#[derive(Debug, Clone)]
pub struct SkewStats {
    /// Job name.
    pub job: String,
    /// Reducer count.
    pub reducers: usize,
    /// Mean bytes fetched per reducer.
    pub mean_bytes: f64,
    /// Bytes fetched by the heaviest reducer.
    pub max_bytes: u64,
    /// max/mean ratio (1.0 = perfectly balanced).
    pub skew: f64,
}

/// Bytes-skew of every reduce job that fetched anything.
pub fn reduce_skew(data: &TraceData) -> Vec<SkewStats> {
    data.jobs
        .iter()
        .filter(|j| !j.reducer_bytes.is_empty())
        .filter_map(|j| {
            let total: u64 = j.reducer_bytes.iter().sum();
            if total == 0 {
                return None;
            }
            let max = *j.reducer_bytes.iter().max().unwrap();
            let mean = total as f64 / j.reducer_bytes.len() as f64;
            Some(SkewStats {
                job: j.name.clone(),
                reducers: j.reducer_bytes.len(),
                mean_bytes: mean,
                max_bytes: max,
                skew: max as f64 / mean,
            })
        })
        .collect()
}

/// Full analysis report: critical path + stragglers + reducer skew (what
/// `psch run --trace-out` prints after the summary table).
pub fn render_report(data: &TraceData, top_k: usize) -> String {
    let mut out = analyze(data, top_k).render(data.makespan_s);
    for s in stragglers(data) {
        let phase = if s.phase.is_empty() { "(none)" } else { &s.phase };
        out.push_str(&format!(
            "stragglers[{phase}]: attempts={} p50={:.2}s p95={:.2}s max={:.2}s\n",
            s.attempts, s.p50_s, s.p95_s, s.max_s
        ));
    }
    let skews = reduce_skew(data);
    if let Some(worst) = skews.iter().max_by(|a, b| a.skew.total_cmp(&b.skew)) {
        out.push_str(&format!(
            "reduce skew: worst {} max/mean={:.2}x ({} reducers, max {} bytes)\n",
            worst.job, worst.skew, worst.reducers, worst.max_bytes
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{JobRec, Segment, TraceData};
    use super::*;

    fn seg(kind: &str, s: f64) -> Segment {
        Segment { kind: kind.to_string(), detail: String::new(), seconds: s }
    }

    fn data() -> TraceData {
        TraceData {
            slaves: 2,
            slots_per_slave: 2,
            makespan_s: 20.0,
            phases: Vec::new(),
            jobs: vec![
                JobRec {
                    name: "sim:deg".into(),
                    phase: "similarity".into(),
                    start_s: 0.0,
                    virtual_s: 12.0,
                    segments: vec![seg("setup", 2.0), seg("map", 6.0), seg("reduce", 4.0)],
                    map_durations: vec![1.0, 6.0],
                    reduce_durations: vec![4.0],
                    reducer_bytes: vec![100, 300],
                },
                JobRec {
                    name: "km:update".into(),
                    phase: "kmeans".into(),
                    start_s: 12.0,
                    virtual_s: 8.0,
                    segments: vec![seg("setup", 2.0), seg("map", 6.0)],
                    map_durations: vec![6.0],
                    reduce_durations: Vec::new(),
                    reducer_bytes: Vec::new(),
                },
            ],
            spans: Vec::new(),
            instants: Vec::new(),
        }
    }

    #[test]
    fn totals_equal_makespan_and_shares_add_up() {
        let d = data();
        let cp = analyze(&d, 3);
        assert!((cp.total_s - d.makespan_s).abs() < 1e-9);
        assert_eq!(cp.jobs, 2);
        let phase_sum: f64 = cp.by_phase.iter().map(|p| p.seconds).sum();
        let kind_sum: f64 = cp.by_kind.iter().map(|k| k.seconds).sum();
        assert!((phase_sum - cp.total_s).abs() < 1e-9);
        assert!((kind_sum - cp.total_s).abs() < 1e-9);
        assert_eq!(cp.top.len(), 3);
        assert_eq!(cp.top[0].seconds, 6.0);
        // by_kind descends: map (12) > setup (4) = reduce (4).
        assert_eq!(cp.by_kind[0].kind, "map");
        let text = cp.render(d.makespan_s);
        assert!(text.starts_with("critical path: "), "{text}");
        assert!(text.contains("similarity"), "{text}");
    }

    #[test]
    fn straggler_percentiles_and_skew() {
        let d = data();
        let s = stragglers(&d);
        assert_eq!(s.len(), 2);
        let sim = &s[0];
        assert_eq!(sim.phase, "similarity");
        assert_eq!(sim.attempts, 3);
        assert_eq!(sim.max_s, 6.0);
        assert_eq!(sim.p50_s, 4.0);
        let skews = reduce_skew(&d);
        assert_eq!(skews.len(), 1);
        assert_eq!(skews[0].reducers, 2);
        assert!((skews[0].skew - 1.5).abs() < 1e-12);
        let report = render_report(&d, 2);
        assert!(report.contains("stragglers[similarity]"), "{report}");
        assert!(report.contains("reduce skew: worst sim:deg"), "{report}");
    }

    #[test]
    fn empty_trace_renders_without_panicking() {
        let d = TraceData {
            slaves: 1,
            slots_per_slave: 1,
            makespan_s: 0.0,
            phases: Vec::new(),
            jobs: Vec::new(),
            spans: Vec::new(),
            instants: Vec::new(),
        };
        let cp = analyze(&d, 5);
        assert_eq!(cp.total_s, 0.0);
        assert!(cp.render(0.0).contains("critical path: 0.000000s"));
        assert!(stragglers(&d).is_empty());
        assert!(reduce_skew(&d).is_empty());
    }
}
