//! Chrome trace-event JSON export (the Perfetto / `chrome://tracing`
//! legacy format): one `"X"` complete event per span, `"i"` instants for
//! deaths/blacklists, `"M"` metadata naming the tracks.
//!
//! Track layout: `tid 0` is the driver lane (run / phase / job / setup /
//! fetch-barrier spans), `tid 1 + global_slot` is one slave execution
//! slot. Timestamps are virtual microseconds — `round(t * 1e6)` of the
//! span's virtual seconds — so the file is byte-identical across runs with
//! the same seed (rounding is monotone, so nesting survives quantization).

use super::json::esc;
use super::{ArgValue, InstantEvent, Span, SpanKind, TraceData};

/// Virtual seconds → whole microseconds (the trace-event `ts` unit).
pub fn us(t: f64) -> u64 {
    (t * 1e6).round().max(0.0) as u64
}

fn args_json(args: &[(&'static str, ArgValue)]) -> String {
    let fields: Vec<String> = args
        .iter()
        .map(|(k, v)| match v {
            ArgValue::U64(x) => format!("\"{k}\":{x}"),
            ArgValue::Str(s) => format!("\"{k}\":\"{}\"", esc(s)),
        })
        .collect();
    format!("{{{}}}", fields.join(","))
}

fn x_event(name: &str, cat: &str, tid: usize, start_s: f64, end_s: f64, args: &str) -> String {
    let ts = us(start_s);
    let dur = us(end_s).saturating_sub(ts);
    format!(
        "{{\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\"name\":\"{}\",\"cat\":\"{cat}\",\
         \"ts\":{ts},\"dur\":{dur},\"args\":{args}}}",
        esc(name)
    )
}

fn span_event(s: &Span) -> String {
    x_event(
        &s.name,
        s.kind.as_str(),
        s.track,
        s.start_s,
        s.end_s,
        &args_json(&s.args),
    )
}

fn instant_event(i: &InstantEvent) -> String {
    format!(
        "{{\"ph\":\"i\",\"pid\":0,\"tid\":0,\"name\":\"{}\",\"s\":\"g\",\
         \"ts\":{},\"args\":{}}}",
        esc(i.name),
        us(i.time_s),
        args_json(&i.args)
    )
}

fn meta_event(tid: usize, which: &str, value: &str) -> String {
    format!(
        "{{\"ph\":\"M\",\"pid\":0,\"tid\":{tid},\"name\":\"{which}\",\
         \"args\":{{\"name\":\"{}\"}}}}",
        esc(value)
    )
}

/// Render the whole trace as a Chrome trace-event JSON document.
pub fn chrome_trace_json(data: &TraceData) -> String {
    let mut events: Vec<String> = Vec::with_capacity(data.spans.len() + 16);
    events.push(meta_event(0, "process_name", "psch virtual cluster"));
    events.push(meta_event(0, "thread_name", "driver"));
    for slave in 0..data.slaves {
        for slot in 0..data.slots_per_slave {
            let tid = 1 + slave * data.slots_per_slave + slot;
            events.push(meta_event(tid, "thread_name", &format!("slave{slave}/slot{slot}")));
        }
    }
    events.push(x_event(
        "run",
        SpanKind::Run.as_str(),
        0,
        0.0,
        data.makespan_s,
        "{}",
    ));
    for p in &data.phases {
        events.push(x_event(
            &p.name,
            SpanKind::Phase.as_str(),
            0,
            p.start_s,
            p.end_s,
            "{}",
        ));
    }
    events.extend(data.spans.iter().map(span_event));
    events.extend(data.instants.iter().map(instant_event));
    format!(
        "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n{}\n]}}\n",
        events.join(",\n")
    )
}

#[cfg(test)]
mod tests {
    use super::super::json::Value;
    use super::super::{PhaseRec, TraceData};
    use super::*;

    fn tiny_trace() -> TraceData {
        TraceData {
            slaves: 2,
            slots_per_slave: 2,
            makespan_s: 10.0,
            phases: vec![PhaseRec {
                name: "similarity".into(),
                start_s: 0.0,
                end_s: 10.0,
            }],
            jobs: Vec::new(),
            spans: vec![Span {
                kind: SpanKind::Attempt,
                name: "map t0".into(),
                track: 1,
                start_s: 1.25,
                end_s: 2.75,
                args: vec![("task", ArgValue::U64(0))],
            }],
            instants: vec![InstantEvent {
                name: "node-death",
                time_s: 3.0,
                args: vec![("slave", ArgValue::U64(1))],
            }],
        }
    }

    #[test]
    fn export_is_valid_json_with_expected_events() {
        let text = chrome_trace_json(&tiny_trace());
        let v = Value::parse(&text).expect("valid JSON");
        let events = v.get("traceEvents").unwrap().items().unwrap();
        // 1 process_name + 5 thread_names (driver + 4 slots) + run + phase
        // + attempt + instant.
        assert_eq!(events.len(), 10);
        let attempt = events
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some("map t0"))
            .unwrap();
        assert_eq!(attempt.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(attempt.get("ts").unwrap().as_u64(), Some(1_250_000));
        assert_eq!(attempt.get("dur").unwrap().as_u64(), Some(1_500_000));
        assert_eq!(attempt.get("tid").unwrap().as_u64(), Some(1));
        assert_eq!(
            attempt.get("args").unwrap().get("task").unwrap().as_u64(),
            Some(0)
        );
        let death = events
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some("node-death"))
            .unwrap();
        assert_eq!(death.get("ph").unwrap().as_str(), Some("i"));
    }

    #[test]
    fn microsecond_rounding_is_monotone() {
        // Monotonicity is what preserves nesting under quantization.
        let mut prev = 0u64;
        for i in 0..1000 {
            let t = i as f64 * 0.000_001_7;
            let u = us(t);
            assert!(u >= prev);
            prev = u;
        }
        assert_eq!(us(-1.0), 0, "negative times clamp to zero");
    }

    #[test]
    fn export_is_deterministic() {
        let a = chrome_trace_json(&tiny_trace());
        let b = chrome_trace_json(&tiny_trace());
        assert_eq!(a, b);
    }
}
