//! Minimal JSON support for the trace subsystem (no serde in the offline
//! vendor set): string escaping + float formatting for the writers, and a
//! small recursive-descent parser so tests can structurally validate what
//! the exporters emit instead of grepping strings.

use std::collections::BTreeMap;

/// Escape a string for embedding between JSON double quotes.
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format an `f64` as a JSON number. Rust's shortest-roundtrip `Display`
/// never uses exponent notation, so the output is always a valid JSON
/// number; non-finite values (which JSON cannot represent) become 0.
pub fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "0".to_string()
    }
}

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (sorted by key; duplicate keys keep the last value).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Value, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The array items (`None` for non-arrays).
    pub fn items(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string payload (`None` for non-strings).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload (`None` for non-numbers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric payload as u64 (rounded; `None` for non-numbers).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x.round() as u64)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", c as char, pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_obj(b, pos),
        Some(b'[') => parse_arr(b, pos),
        Some(b'"') => parse_str(b, pos).map(Value::Str),
        Some(b't') => parse_lit(b, pos, "true", Value::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Value::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Value::Null),
        Some(_) => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Value) -> Result<Value, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Value, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("bad number {s:?} at byte {start}"))
}

fn parse_str(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or("truncated \\u escape")?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                            16,
                        )
                        .map_err(|e| e.to_string())?;
                        // Lone surrogates degrade to U+FFFD; the trace
                        // writers never emit astral-plane escapes.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (multi-byte safe).
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Value, String> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Value::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Value::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos}")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Value, String> {
    expect(b, pos, b'{')?;
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Value::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_str(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Value::Obj(map));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_covers_controls_and_quotes() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(esc("x\ny\t"), "x\\ny\\t");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }

    #[test]
    fn num_is_json_safe() {
        assert_eq!(num(1.5), "1.5");
        assert_eq!(num(1.0), "1");
        assert_eq!(num(f64::NAN), "0");
        assert_eq!(num(f64::INFINITY), "0");
        // Tiny values stay decimal (no exponent notation in JSON output).
        assert!(!num(1e-9).contains('e'), "{}", num(1e-9));
    }

    #[test]
    fn parse_roundtrips_a_nested_document() {
        let doc = r#"{"a":[1,2.5,-3],"b":{"c":"x\ny","d":true,"e":null}}"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().items().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().items().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("b").unwrap().get("d"), Some(&Value::Bool(true)));
        assert_eq!(v.get("b").unwrap().get("e"), Some(&Value::Null));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{}x").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn writer_output_parses() {
        let doc = format!("{{\"s\":\"{}\",\"x\":{}}}", esc("he\"llo"), num(0.25));
        let v = Value::parse(&doc).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("he\"llo"));
        assert_eq!(v.get("x").unwrap().as_f64(), Some(0.25));
    }
}
