//! The MapReduce engine: map → (combine) → shuffle/sort → reduce.
//!
//! Runs map and reduce tasks on the [`Cluster`]'s worker pool with per-task
//! retry (Hadoop's task-attempt model), a map-side combiner, a sort-merge
//! shuffle, counters, and virtual-time accounting: every task's measured
//! cost + its split's block locations are replayed through the cluster's
//! JobTracker ([`crate::scheduler`]) — heartbeat-driven slot assignment,
//! node-local/rack-local/off-rack read charging and live speculative
//! duplicates — whose tallies land in the job counters.

use crate::cluster::{Cluster, TaskCost};
use crate::error::{Error, Result};
use crate::scheduler::{SchedulePlan, TaskSpec};

use super::counters::{names, Counters};
use super::job::{Job, Phase};
use super::types::{Bytes, TaskContext, KV};

/// Statistics of one executed job.
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    /// Cost profile of every map task (measured compute + bytes).
    pub map_costs: Vec<TaskCost>,
    /// Cost profile of every reduce task.
    pub reduce_costs: Vec<TaskCost>,
    /// Total intermediate bytes crossing the shuffle.
    pub shuffle_bytes: u64,
    /// Virtual wall-clock on the simulated cluster (seconds).
    pub virtual_time_s: f64,
    /// Real wall-clock of this simulation (seconds).
    pub wall_time_s: f64,
}

/// Result of a job: per-partition sorted output, counters, stats.
#[derive(Debug, Default)]
pub struct JobResult {
    /// For reduce jobs: one sorted record vector per reduce partition.
    /// For map-only jobs: one record vector per map task.
    pub output: Vec<Vec<KV>>,
    /// Merged counters.
    pub counters: Counters,
    /// Cost/timing profile.
    pub stats: JobStats,
}

impl JobResult {
    /// Flatten all partitions into one globally key-sorted record list.
    ///
    /// Moves the records out of `output` (which is left empty) instead of
    /// cloning every KV across all partitions; counters and stats remain.
    pub fn sorted_records(&mut self) -> Vec<KV> {
        let mut all: Vec<KV> = std::mem::take(&mut self.output)
            .into_iter()
            .flatten()
            .collect();
        all.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        all
    }
}

/// Fold one phase plan's locality/speculation tallies into the counters.
fn absorb_plan(counters: &mut Counters, plan: &SchedulePlan, is_map: bool) {
    counters.incr(names::HEARTBEATS, plan.heartbeats);
    counters.incr(names::SPECULATIVE_ATTEMPTS, plan.speculative_attempts as u64);
    counters.incr(names::SPECULATIVE_WINS, plan.speculative_wins as u64);
    if is_map {
        counters.incr(names::DATA_LOCAL_MAPS, plan.node_local as u64);
        counters.incr(names::RACK_LOCAL_MAPS, plan.rack_local as u64);
        counters.incr(names::OFF_RACK_MAPS, plan.off_rack as u64);
        counters.incr(names::MAP_READ_US, (plan.input_read_s * 1e6).round() as u64);
    }
}

/// Run a job on the cluster.
pub fn run(cluster: &Cluster, job: &Job) -> Result<JobResult> {
    let wall_start = std::time::Instant::now();
    let mut counters = Counters::default();

    // ---------------- map phase (with retry) ----------------
    struct MapOut {
        records: Vec<KV>,
        counters: Counters,
        input_bytes: u64,
        failed_attempts: u64,
    }
    let map_tasks: Vec<_> = job
        .input
        .iter()
        .enumerate()
        .map(|(task_id, split)| {
            let mapper = job.mapper.clone();
            let combiner = job.combiner.clone();
            let fault = job.fault.clone();
            let max_attempts = job.max_attempts;
            move || -> Result<MapOut> {
                let input_bytes: u64 = split
                    .iter()
                    .map(|(k, v)| (k.len() + v.len()) as u64)
                    .sum();
                let mut failed = 0u64;
                for attempt in 0..max_attempts {
                    if let Some(f) = &fault {
                        if f(Phase::Map, task_id, attempt) {
                            failed += 1;
                            continue;
                        }
                    }
                    let mut ctx = TaskContext::default();
                    let mut ok = true;
                    for (k, v) in split {
                        ctx.incr(names::MAP_INPUT_RECORDS, 1);
                        if mapper.map(k, v, &mut ctx).is_err() {
                            failed += 1;
                            ok = false;
                            break;
                        }
                    }
                    if !ok {
                        continue;
                    }
                    let (mut records, mut task_counters) = ctx.into_parts();
                    task_counters.incr(names::MAP_OUTPUT_RECORDS, records.len() as u64);
                    // Map-side combine: sort-group-reduce within this task.
                    if let Some(c) = &combiner {
                        records = combine(records, c.as_ref())?;
                        task_counters
                            .incr(names::COMBINE_OUTPUT_RECORDS, records.len() as u64);
                    }
                    return Ok(MapOut {
                        records,
                        counters: task_counters,
                        input_bytes,
                        failed_attempts: failed,
                    });
                }
                Err(Error::MapReduce(format!(
                    "map task {task_id} failed after {max_attempts} attempts"
                )))
            }
        })
        .collect();

    let map_results = cluster.execute(map_tasks)?;
    let mut map_costs = Vec::with_capacity(map_results.len());
    let mut map_outputs: Vec<Vec<KV>> = Vec::with_capacity(map_results.len());
    for (out, secs) in map_results {
        let out_bytes: u64 = out
            .records
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum();
        let modeled_us = out.counters.get(names::COMPUTE_US);
        map_costs.push(TaskCost {
            // Deterministic modeled compute wins over noisy measured time.
            compute_s: if modeled_us > 0 { modeled_us as f64 / 1e6 } else { secs },
            input_bytes: out.input_bytes
                + out.counters.get(names::EXTRA_INPUT_BYTES),
            output_bytes: out_bytes
                + out.counters.get(names::EXTRA_OUTPUT_BYTES),
        });
        counters.merge(&out.counters);
        counters.incr(names::FAILED_MAP_ATTEMPTS, out.failed_attempts);
        map_outputs.push(out.records);
    }

    // Route the map phase through the JobTracker: measured costs + declared
    // split locations drive heartbeat slot assignment, locality-tiered read
    // charging and live speculation.
    let map_specs: Vec<TaskSpec> = map_costs
        .iter()
        .enumerate()
        .map(|(i, c)| TaskSpec {
            cost: *c,
            hosts: job.split_hosts.get(i).cloned().unwrap_or_default(),
        })
        .collect();
    let map_plan = cluster.plan_phase(&map_specs);
    absorb_plan(&mut counters, &map_plan, true);

    // ---------------- map-only job: done ----------------
    let Some(reducer) = &job.reducer else {
        let stats = JobStats {
            shuffle_bytes: 0,
            virtual_time_s: cluster.planned_job_time(&map_plan, None, 0),
            wall_time_s: wall_start.elapsed().as_secs_f64(),
            map_costs,
            reduce_costs: vec![],
        };
        return Ok(JobResult { output: map_outputs, counters, stats });
    };

    // ---------------- shuffle: partition + sort + group ----------------
    let nred = job.num_reducers;
    let mut partitions: Vec<Vec<KV>> = (0..nred).map(|_| Vec::new()).collect();
    let mut shuffle_bytes = 0u64;
    for records in map_outputs {
        for (k, v) in records {
            shuffle_bytes += (k.len() + v.len()) as u64;
            let p = job.partitioner.partition(&k, nred);
            partitions[p].push((k, v));
        }
    }
    counters.incr(names::SHUFFLE_BYTES, shuffle_bytes);
    for p in partitions.iter_mut() {
        p.sort_by(|a, b| a.0.cmp(&b.0));
    }

    // ---------------- reduce phase (with retry) ----------------
    struct RedOut {
        records: Vec<KV>,
        counters: Counters,
        input_bytes: u64,
        failed_attempts: u64,
    }
    let reduce_tasks: Vec<_> = partitions
        .into_iter()
        .enumerate()
        .map(|(task_id, part)| {
            let reducer = reducer.clone();
            let fault = job.fault.clone();
            let max_attempts = job.max_attempts;
            move || -> Result<RedOut> {
                let input_bytes: u64 =
                    part.iter().map(|(k, v)| (k.len() + v.len()) as u64).sum();
                let mut failed = 0u64;
                for attempt in 0..max_attempts {
                    if let Some(f) = &fault {
                        if f(Phase::Reduce, task_id, attempt) {
                            failed += 1;
                            continue;
                        }
                    }
                    let mut ctx = TaskContext::default();
                    let mut groups = 0u64;
                    let mut ok = true;
                    let mut i = 0;
                    while i < part.len() {
                        let key = &part[i].0;
                        let mut j = i;
                        while j < part.len() && &part[j].0 == key {
                            j += 1;
                        }
                        let values: Vec<Bytes> =
                            part[i..j].iter().map(|(_, v)| v.clone()).collect();
                        groups += 1;
                        if reducer.reduce(key, &values, &mut ctx).is_err() {
                            failed += 1;
                            ok = false;
                            break;
                        }
                        i = j;
                    }
                    if !ok {
                        continue;
                    }
                    let (records, mut task_counters) = ctx.into_parts();
                    task_counters.incr(names::REDUCE_INPUT_GROUPS, groups);
                    task_counters
                        .incr(names::REDUCE_OUTPUT_RECORDS, records.len() as u64);
                    return Ok(RedOut {
                        records,
                        counters: task_counters,
                        input_bytes,
                        failed_attempts: failed,
                    });
                }
                Err(Error::MapReduce(format!(
                    "job: reduce task {task_id} failed after {max_attempts} attempts"
                )))
            }
        })
        .collect();

    let reduce_results = cluster.execute(reduce_tasks)?;
    let mut reduce_costs = Vec::with_capacity(reduce_results.len());
    let mut output = Vec::with_capacity(reduce_results.len());
    for (out, secs) in reduce_results {
        let out_bytes: u64 = out
            .records
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum();
        let modeled_us = out.counters.get(names::COMPUTE_US);
        reduce_costs.push(TaskCost {
            compute_s: if modeled_us > 0 { modeled_us as f64 / 1e6 } else { secs },
            input_bytes: out.input_bytes,
            output_bytes: out_bytes,
        });
        counters.merge(&out.counters);
        counters.incr(names::FAILED_REDUCE_ATTEMPTS, out.failed_attempts);
        output.push(out.records);
    }

    // Reducers pull their input through the shuffle (charged separately),
    // so their plan carries no locality preference.
    let reduce_specs: Vec<TaskSpec> = reduce_costs
        .iter()
        .map(|c| TaskSpec { cost: *c, hosts: Vec::new() })
        .collect();
    let reduce_plan = cluster.plan_phase(&reduce_specs);
    absorb_plan(&mut counters, &reduce_plan, false);

    let stats = JobStats {
        virtual_time_s: cluster.planned_job_time(
            &map_plan,
            Some(&reduce_plan),
            shuffle_bytes,
        ),
        wall_time_s: wall_start.elapsed().as_secs_f64(),
        map_costs,
        reduce_costs,
        shuffle_bytes,
    };
    Ok(JobResult { output, counters, stats })
}

/// Sort-group-apply a combiner to one map task's output.
fn combine(mut records: Vec<KV>, combiner: &dyn super::types::Reducer) -> Result<Vec<KV>> {
    records.sort_by(|a, b| a.0.cmp(&b.0));
    let mut ctx = TaskContext::default();
    let mut i = 0;
    while i < records.len() {
        let key = records[i].0.clone();
        let mut j = i;
        while j < records.len() && records[j].0 == key {
            j += 1;
        }
        let values: Vec<Bytes> = records[i..j].iter().map(|(_, v)| v.clone()).collect();
        combiner.reduce(&key, &values, &mut ctx)?;
        i = j;
    }
    let (out, _) = ctx.into_parts();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::job::JobBuilder;
    use crate::mapreduce::types::{FnMapper, FnReducer};
    use crate::util::bytes::{decode_u64, encode_u64};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn word_splits() -> Vec<Vec<KV>> {
        // Two splits of words.
        vec![
            vec![
                (vec![], b"the quick brown fox".to_vec()),
                (vec![], b"the lazy dog".to_vec()),
            ],
            vec![(vec![], b"the fox jumps over the dog".to_vec())],
        ]
    }

    fn wordcount_job(input: Vec<Vec<KV>>, with_combiner: bool) -> Job {
        let mapper = Arc::new(FnMapper(|_k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            for w in std::str::from_utf8(v).unwrap().split_whitespace() {
                ctx.emit(w.as_bytes().to_vec(), encode_u64(1).to_vec());
            }
            Ok(())
        }));
        let sum = Arc::new(FnReducer(
            |k: &[u8], vs: &[Bytes], ctx: &mut TaskContext| {
                let total: u64 = vs.iter().map(|v| decode_u64(v)).sum();
                ctx.emit(k.to_vec(), encode_u64(total).to_vec());
                Ok(())
            },
        ));
        let mut b = JobBuilder::new("wordcount", input, mapper).reducer(sum.clone(), 3);
        if with_combiner {
            b = b.combiner(sum);
        }
        b.build()
    }

    fn counts_of(result: &mut JobResult) -> std::collections::HashMap<String, u64> {
        result
            .sorted_records()
            .into_iter()
            .map(|(k, v)| (String::from_utf8(k).unwrap(), decode_u64(&v)))
            .collect()
    }

    #[test]
    fn wordcount_end_to_end() {
        let cluster = Cluster::new(4);
        let job = wordcount_job(word_splits(), false);
        let mut result = run(&cluster, &job).unwrap();
        let counts = counts_of(&mut result);
        assert_eq!(counts["the"], 4);
        assert_eq!(counts["fox"], 2);
        assert_eq!(counts["dog"], 2);
        assert_eq!(counts["quick"], 1);
        assert_eq!(result.counters.get(names::MAP_INPUT_RECORDS), 3);
        assert!(result.stats.virtual_time_s > 0.0);
    }

    #[test]
    fn combiner_reduces_shuffle_but_not_answer() {
        let cluster = Cluster::new(2);
        let mut plain = run(&cluster, &wordcount_job(word_splits(), false)).unwrap();
        let mut combined = run(&cluster, &wordcount_job(word_splits(), true)).unwrap();
        assert_eq!(counts_of(&mut plain), counts_of(&mut combined));
        assert!(
            combined.stats.shuffle_bytes < plain.stats.shuffle_bytes,
            "combiner should shrink shuffle: {} vs {}",
            combined.stats.shuffle_bytes,
            plain.stats.shuffle_bytes
        );
    }

    #[test]
    fn map_only_job_returns_per_task_output() {
        let cluster = Cluster::new(2);
        let mapper = Arc::new(FnMapper(|k: &[u8], _v: &[u8], ctx: &mut TaskContext| {
            ctx.emit(k.to_vec(), b"x".to_vec());
            Ok(())
        }));
        let input = vec![
            vec![(vec![1], vec![]), (vec![2], vec![])],
            vec![(vec![3], vec![])],
        ];
        let job = JobBuilder::new("maponly", input, mapper).build();
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.output.len(), 2); // one per map task
        assert_eq!(r.output[0].len(), 2);
        assert_eq!(r.output[1].len(), 1);
        assert_eq!(r.stats.shuffle_bytes, 0);
    }

    #[test]
    fn transient_fault_retried_to_success() {
        let cluster = Cluster::new(2);
        let mut job = wordcount_job(word_splits(), false);
        // Fail the first two attempts of map task 0 and the first attempt of
        // reduce task 1; all should recover within 4 attempts.
        job.fault = Some(Arc::new(|phase, task, attempt| match phase {
            Phase::Map => task == 0 && attempt < 2,
            Phase::Reduce => task == 1 && attempt < 1,
        }));
        let mut r = run(&cluster, &job).unwrap();
        assert_eq!(counts_of(&mut r)["the"], 4);
        assert_eq!(r.counters.get(names::FAILED_MAP_ATTEMPTS), 2);
        assert_eq!(r.counters.get(names::FAILED_REDUCE_ATTEMPTS), 1);
    }

    #[test]
    fn permanent_fault_fails_job() {
        let cluster = Cluster::new(2);
        let mut job = wordcount_job(word_splits(), false);
        job.max_attempts = 3;
        job.fault = Some(Arc::new(|phase, task, _| {
            phase == Phase::Map && task == 1
        }));
        let err = run(&cluster, &job).unwrap_err();
        assert!(err.to_string().contains("failed after 3 attempts"), "{err}");
    }

    #[test]
    fn mapper_error_also_retried() {
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let cluster = Cluster::new(1);
        let mapper = Arc::new(FnMapper(|_k: &[u8], _v: &[u8], _ctx: &mut TaskContext| {
            // First invocation errors, later ones succeed.
            if CALLS.fetch_add(1, Ordering::SeqCst) == 0 {
                Err(Error::MapReduce("flaky".into()))
            } else {
                Ok(())
            }
        }));
        let job = JobBuilder::new("flaky", vec![vec![(vec![], vec![])]], mapper).build();
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.counters.get(names::FAILED_MAP_ATTEMPTS), 1);
    }

    #[test]
    fn reduce_outputs_sorted_within_partition() {
        let cluster = Cluster::new(2);
        let job = wordcount_job(word_splits(), false);
        let r = run(&cluster, &job).unwrap();
        for part in &r.output {
            for w in part.windows(2) {
                assert!(w[0].0 <= w[1].0, "partition not sorted");
            }
        }
    }

    #[test]
    fn every_emitted_key_lands_in_exactly_one_partition() {
        // Routing invariant: reducers together see every mapped record once.
        let cluster = Cluster::new(3);
        let job = wordcount_job(word_splits(), false);
        let mut r = run(&cluster, &job).unwrap();
        let total: u64 = counts_of(&mut r).values().sum();
        assert_eq!(total, 13, "13 words in the corpus");
    }

    #[test]
    fn split_hosts_flow_into_locality_counters() {
        let mut cluster =
            Cluster::with_model(2, 2, crate::cluster::NetworkModel::default());
        cluster.set_topology(crate::scheduler::RackTopology::uniform(2, 2));
        let mut job = wordcount_job(word_splits(), false);
        job.split_hosts = vec![vec![0], vec![1]];
        let r = run(&cluster, &job).unwrap();
        let placed = r.counters.get(names::DATA_LOCAL_MAPS)
            + r.counters.get(names::RACK_LOCAL_MAPS)
            + r.counters.get(names::OFF_RACK_MAPS);
        assert_eq!(placed, 2, "both located splits must be tallied");
        assert!(r.counters.get(names::HEARTBEATS) > 0);
        // The default locality-first policy finds both node-local homes.
        assert_eq!(r.counters.get(names::DATA_LOCAL_MAPS), 2);
    }

    #[test]
    fn jobs_without_hosts_stay_out_of_locality_tallies() {
        let cluster = Cluster::new(2);
        let job = wordcount_job(word_splits(), false);
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.counters.get(names::DATA_LOCAL_MAPS), 0);
        assert_eq!(r.counters.get(names::RACK_LOCAL_MAPS), 0);
        assert_eq!(r.counters.get(names::OFF_RACK_MAPS), 0);
    }
}
