//! The MapReduce engine: map → spill/merge → fetch → merge → reduce.
//!
//! Runs map and reduce tasks on the [`Cluster`]'s worker pool, the
//! [`super::shuffle`] subsystem (map-side sort/spill/merge with a
//! per-spill combiner, reduce-side locality-charged fetches and a
//! streaming grouped merge), counters, and virtual-time accounting: every
//! task's measured cost + its split's block locations are replayed through
//! the cluster's JobTracker ([`crate::scheduler`]) — heartbeat-driven slot
//! assignment, node-local/rack-local/off-rack read charging and live
//! speculative duplicates — whose tallies land in the job counters.
//!
//! Failure handling is cluster-wide (DESIGN.md §2.9), not a per-job retry
//! loop: real task errors surface to the engine, which re-executes only
//! the failed tasks on fresh rounds (completed siblings' results are
//! reused, never recomputed); the failure domain
//! ([`crate::cluster::faults`]) injects virtual attempt failures and node
//! deaths into the JobTracker plans; and a reduce fetch that targets a
//! dead slave's map output triggers re-execution of that completed map on
//! a live node (`MAP_RERUNS` / `FETCH_FAILURES`).

use crate::cluster::{Cluster, TaskCost};
use crate::error::{Error, Result};
use crate::scheduler::{SchedulePlan, TaskSpec};
use crate::trace;

use super::counters::{names, Counters};
use super::job::Job;
use super::shuffle::{self, GroupedMerge, MapShuffleOutput, Segment, SpillCollector};
use super::types::{TaskContext, KV};

/// Statistics of one executed job.
#[derive(Debug, Clone, Default)]
pub struct JobStats {
    /// Cost profile of every map task (measured compute + bytes).
    pub map_costs: Vec<TaskCost>,
    /// Cost profile of every reduce task.
    pub reduce_costs: Vec<TaskCost>,
    /// Total intermediate bytes crossing the shuffle (post-combine).
    pub shuffle_bytes: u64,
    /// Records written in map spills and re-written in merge passes.
    pub spilled_records: u64,
    /// Merge passes across map and reduce sides.
    pub merge_passes: u64,
    /// Virtual seconds of the slowest reducer's fetch phase.
    pub shuffle_fetch_s: f64,
    /// Virtual wall-clock on the simulated cluster (seconds).
    pub virtual_time_s: f64,
    /// Real wall-clock of this simulation (seconds).
    pub wall_time_s: f64,
}

/// Result of a job: per-partition sorted output, counters, stats.
#[derive(Debug, Default)]
pub struct JobResult {
    /// For reduce jobs: one sorted record vector per reduce partition.
    /// For map-only jobs: one record vector per map task.
    pub output: Vec<Vec<KV>>,
    /// Merged counters.
    pub counters: Counters,
    /// Cost/timing profile.
    pub stats: JobStats,
}

impl JobResult {
    /// Flatten all partitions into one globally key-sorted record list.
    ///
    /// Moves the records out of `output` (which is left empty) instead of
    /// cloning every KV across all partitions; counters and stats remain.
    pub fn sorted_records(&mut self) -> Vec<KV> {
        let mut all: Vec<KV> = std::mem::take(&mut self.output)
            .into_iter()
            .flatten()
            .collect();
        all.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        all
    }
}

/// Fold one phase plan's locality/speculation/fault tallies into the
/// counters. `total_slots` sizes the idle-capacity charge: makespan ×
/// slots minus attempt occupancy.
fn absorb_plan(
    counters: &mut Counters,
    plan: &SchedulePlan,
    is_map: bool,
    total_slots: usize,
) {
    counters.incr(names::HEARTBEATS, plan.heartbeats);
    counters.incr(
        names::QUEUE_WAIT_US,
        (plan.queue_wait_s() * 1e6).round() as u64,
    );
    counters.incr(
        names::SLOT_IDLE_US,
        (plan.slot_idle_s(total_slots) * 1e6).round() as u64,
    );
    counters.incr(names::SPECULATIVE_ATTEMPTS, plan.speculative_attempts as u64);
    counters.incr(names::SPECULATIVE_WINS, plan.speculative_wins as u64);
    counters.incr(names::NODE_DEATHS, plan.deaths);
    counters.incr(names::BLACKLISTED_SLAVES, plan.blacklisted.len() as u64);
    counters.incr(
        if is_map {
            names::FAILED_MAP_ATTEMPTS
        } else {
            names::FAILED_REDUCE_ATTEMPTS
        },
        plan.failed_attempts,
    );
    if is_map {
        counters.incr(names::DATA_LOCAL_MAPS, plan.node_local as u64);
        counters.incr(names::RACK_LOCAL_MAPS, plan.rack_local as u64);
        counters.incr(names::OFF_RACK_MAPS, plan.off_rack as u64);
        counters.incr(names::MAP_READ_US, (plan.input_read_s * 1e6).round() as u64);
    }
}

/// Turn a phase plan with unrecoverable tasks into the job error.
fn check_plan(plan: &SchedulePlan, phase: &str, job: &str) -> Result<()> {
    if let Some(&task) = plan.failed_tasks.first() {
        return Err(Error::MapReduce(format!(
            "job {job}: {phase} task {task} could not complete \
             ({} task(s) exhausted their attempts or lost every slave)",
            plan.failed_tasks.len()
        )));
    }
    Ok(())
}

/// Re-execute `tasks` (engine-level re-planning) until every slot holds a
/// result or a task has failed `max_rounds` real attempts. Completed
/// results from earlier rounds are always reused. Returns the results (in
/// task order) and the number of real failed attempts observed.
fn execute_with_retry<T, F>(
    cluster: &Cluster,
    n: usize,
    make_task: impl Fn(usize) -> F,
    what: &str,
    job: &str,
) -> Result<(Vec<(T, f64)>, u64)>
where
    T: Send,
    F: FnOnce() -> Result<T> + Send,
{
    let max_rounds = cluster.faults().config().max_attempts.max(1);
    let mut slots: Vec<Option<(T, f64)>> = (0..n).map(|_| None).collect();
    let mut failed_attempts = 0u64;
    for round in 0..max_rounds {
        let todo: Vec<usize> = (0..n).filter(|&i| slots[i].is_none()).collect();
        if todo.is_empty() {
            break;
        }
        let tasks: Vec<F> = todo.iter().map(|&i| make_task(i)).collect();
        let mut outcome = cluster.execute(tasks);
        for (j, slot) in outcome.results.drain(..).enumerate() {
            if let Some(r) = slot {
                slots[todo[j]] = Some(r);
            }
        }
        failed_attempts += outcome.failures.len() as u64;
        if let Some((j, e)) = outcome.failures.into_iter().next() {
            if round + 1 == max_rounds {
                return Err(Error::MapReduce(format!(
                    "job {job}: {what} task {} failed after {max_rounds} attempts: {e}",
                    todo[j]
                )));
            }
        }
    }
    let results = slots
        .into_iter()
        .map(|s| s.expect("every slot filled or an error returned"))
        .collect();
    Ok((results, failed_attempts))
}

/// Run a job on the cluster.
pub fn run(cluster: &Cluster, job: &Job) -> Result<JobResult> {
    let wall_start = std::time::Instant::now();
    let mut counters = Counters::default();
    let shuffle_cfg = job.shuffle.unwrap_or(*cluster.shuffle_config());
    let has_reducer = job.reducer.is_some();
    // Clamp once here so a hand-built Job (bypassing JobBuilder's clamp)
    // agrees with SpillCollector's own floor of one partition.
    let nred = job.num_reducers.max(1);

    // ---------------- map phase ----------------
    struct MapOut {
        /// Spilled/merged per-partition segments (reduce jobs).
        shuffle: Option<MapShuffleOutput>,
        /// Raw emitted records (map-only jobs).
        records: Vec<KV>,
        counters: Counters,
        input_bytes: u64,
    }
    // One single-attempt task per split; a real error surfaces to
    // `execute_with_retry`, which re-runs only the failed tasks.
    let make_map_task = |task_id: usize| {
        let split = &job.input[task_id];
        let mapper = job.mapper.clone();
        let combiner = job.combiner.clone();
        let partitioner = job.partitioner.clone();
        move || -> Result<MapOut> {
            let input_bytes: u64 = split
                .iter()
                .map(|(k, v)| (k.len() + v.len()) as u64)
                .sum();
            let mut ctx = TaskContext::default();
            // Reduce jobs route emits through the spill buffer; a
            // map-only job's emits ARE its output and stay put.
            let mut collector = has_reducer.then(|| {
                SpillCollector::new(nred, partitioner, combiner.clone(), shuffle_cfg)
            });
            for (k, v) in split {
                ctx.incr(names::MAP_INPUT_RECORDS, 1);
                mapper.map(k, v, &mut ctx)?;
                if let Some(col) = collector.as_mut() {
                    for (kk, vv) in ctx.take_emits() {
                        col.collect(kk, vv)?;
                    }
                }
            }
            let (records, mut task_counters) = ctx.into_parts();
            let (records, shuffle_out) = match collector {
                Some(col) => {
                    let out = col.finish()?;
                    task_counters.incr(names::MAP_OUTPUT_RECORDS, out.input_records);
                    if combiner.is_some() {
                        task_counters.incr(
                            names::COMBINE_OUTPUT_RECORDS,
                            out.combine_output_records,
                        );
                    }
                    task_counters.incr(names::SPILLS, out.spills);
                    task_counters.incr(names::SPILLED_RECORDS, out.spilled_records);
                    task_counters.incr(names::MERGE_PASSES, out.merge_passes);
                    (Vec::new(), Some(out))
                }
                None => {
                    task_counters
                        .incr(names::MAP_OUTPUT_RECORDS, records.len() as u64);
                    // A map-only job's combiner still runs over the
                    // task output (sort-group-combine, as the
                    // pre-shuffle engine did).
                    let records = match &combiner {
                        Some(c) => {
                            let combined = shuffle::buffer::combine_segment(
                                Segment::from_unsorted(records),
                                c.as_ref(),
                            )?
                            .into_records();
                            task_counters.incr(
                                names::COMBINE_OUTPUT_RECORDS,
                                combined.len() as u64,
                            );
                            combined
                        }
                        None => records,
                    };
                    (records, None)
                }
            };
            Ok(MapOut {
                shuffle: shuffle_out,
                records,
                counters: task_counters,
                input_bytes,
            })
        }
    };

    let nmaps = job.input.len();
    let (map_results, real_map_failures) =
        execute_with_retry(cluster, nmaps, make_map_task, "map", &job.name)?;
    counters.incr(names::FAILED_MAP_ATTEMPTS, real_map_failures);
    let mut map_costs = Vec::with_capacity(nmaps);
    let mut map_records: Vec<Vec<KV>> = Vec::new();
    // map_segments[m][p] = map m's sorted output segment for partition p.
    let mut map_segments: Vec<Vec<Segment>> = Vec::new();
    for (out, secs) in map_results {
        let out_bytes: u64 = match &out.shuffle {
            Some(s) => s.bytes(),
            None => out
                .records
                .iter()
                .map(|(k, v)| (k.len() + v.len()) as u64)
                .sum(),
        };
        let modeled_us = out.counters.get(names::COMPUTE_US);
        map_costs.push(TaskCost {
            // Deterministic modeled compute wins over noisy measured time.
            compute_s: if modeled_us > 0 { modeled_us as f64 / 1e6 } else { secs },
            input_bytes: out.input_bytes
                + out.counters.get(names::EXTRA_INPUT_BYTES),
            output_bytes: out_bytes
                + out.counters.get(names::EXTRA_OUTPUT_BYTES),
        });
        counters.merge(&out.counters);
        match out.shuffle {
            Some(s) => map_segments.push(s.segments),
            None => map_records.push(out.records),
        }
    }

    // Route the map phase through the JobTracker: measured costs + declared
    // split locations drive heartbeat slot assignment, locality-tiered read
    // charging, live speculation and the failure domain (injected attempt
    // failures re-plan with fresh locality; node deaths fire here).
    let map_specs: Vec<TaskSpec> = map_costs
        .iter()
        .enumerate()
        .map(|(i, c)| TaskSpec {
            cost: *c,
            hosts: job.split_hosts.get(i).cloned().unwrap_or_default(),
        })
        .collect();
    let map_plan = cluster.plan_phase(&map_specs);
    check_plan(&map_plan, "map", &job.name)?;
    absorb_plan(&mut counters, &map_plan, true, cluster.total_slots());

    // ---------------- map-only job: done ----------------
    let Some(reducer) = &job.reducer else {
        let virtual_time_s = cluster.planned_job_time(&map_plan, None, 0);
        if cluster.trace().enabled() {
            cluster.trace().record_job(trace::JobTrace {
                name: job.name.clone(),
                overhead_s: cluster.model().job_overhead(cluster.num_slaves()),
                virtual_time_s,
                map: trace::plan_trace(&map_plan, &map_specs, cluster.model()),
                reruns: Vec::new(),
                fetch: None,
                reduce: None,
                spill_bytes: Vec::new(),
            });
        }
        let stats = JobStats {
            virtual_time_s,
            wall_time_s: wall_start.elapsed().as_secs_f64(),
            map_costs,
            ..JobStats::default()
        };
        return Ok(JobResult { output: map_records, counters, stats });
    };

    // ---------------- shuffle: per-partition fetch lists ----------------
    // Segment sizes per (map, partition), recorded before the segments move
    // into the reduce tasks — the fetch plan charges these per tier.
    let seg_bytes: Vec<Vec<u64>> = map_segments
        .iter()
        .map(|segs| segs.iter().map(|s| s.bytes()).collect())
        .collect();
    let shuffle_bytes: u64 =
        seg_bytes.iter().map(|row| row.iter().sum::<u64>()).sum();
    counters.incr(names::SHUFFLE_BYTES, shuffle_bytes);
    let mut partitions: Vec<Vec<Segment>> = (0..nred)
        .map(|_| Vec::with_capacity(map_segments.len()))
        .collect();
    for segs in map_segments {
        for (p, seg) in segs.into_iter().enumerate() {
            if !seg.is_empty() {
                partitions[p].push(seg);
            }
        }
    }

    // ---------------- reduce phase ----------------
    struct RedOut {
        records: Vec<KV>,
        counters: Counters,
        input_bytes: u64,
    }
    // Fetch merge: bring each partition's runs under the factor bound once
    // (Hadoop's on-disk merges), on the worker pool so the per-partition
    // merges run concurrently and their measured seconds stay part of the
    // reduce task cost. The streamed final merge is rebuilt per attempt,
    // so re-executed reduce tasks reuse the merged runs.
    let merge_tasks: Vec<_> = partitions
        .into_iter()
        .map(|segments| {
            let factor = shuffle_cfg.factor();
            move || -> Result<(Vec<Segment>, u64, u64, u64)> {
                let input_bytes: u64 = segments.iter().map(|s| s.bytes()).sum();
                let (merged, merge_passes, respilled) =
                    shuffle::merge_to_factor(segments, factor);
                Ok((merged, merge_passes, respilled, input_bytes))
            }
        })
        .collect();
    // (Merge tasks are infallible; into_result never errors here.)
    let prepared: Vec<((Vec<Segment>, u64, u64, u64), f64)> =
        cluster.execute(merge_tasks).into_result()?;
    let make_reduce_task = |task_id: usize| {
        let reducer = reducer.clone();
        let ((merged, merge_passes, respilled, input_bytes), _) = &prepared[task_id];
        move || -> Result<RedOut> {
            let mut ctx = TaskContext::default();
            let mut groups = 0u64;
            let mut gm = GroupedMerge::new(merged);
            while let Some(key) = gm.next_key() {
                groups += 1;
                let mut vs = gm.values();
                reducer.reduce(&key, &mut vs, &mut ctx)?;
            }
            let (records, mut task_counters) = ctx.into_parts();
            task_counters.incr(names::REDUCE_INPUT_GROUPS, groups);
            task_counters.incr(names::REDUCE_OUTPUT_RECORDS, records.len() as u64);
            task_counters.incr(names::MERGE_PASSES, *merge_passes);
            task_counters.incr(names::SPILLED_RECORDS, *respilled);
            Ok(RedOut { records, counters: task_counters, input_bytes: *input_bytes })
        }
    };

    let (reduce_results, real_reduce_failures) =
        execute_with_retry(cluster, prepared.len(), make_reduce_task, "reduce", &job.name)?;
    counters.incr(names::FAILED_REDUCE_ATTEMPTS, real_reduce_failures);
    let mut reduce_costs = Vec::with_capacity(reduce_results.len());
    let mut output = Vec::with_capacity(reduce_results.len());
    for (ti, (out, secs)) in reduce_results.into_iter().enumerate() {
        let out_bytes: u64 = out
            .records
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum();
        let modeled_us = out.counters.get(names::COMPUTE_US);
        // The fetch-merge pre-pass is part of the reduce task's work:
        // charge its measured seconds alongside the reduce attempt's.
        let measured = secs + prepared[ti].1;
        reduce_costs.push(TaskCost {
            compute_s: if modeled_us > 0 { modeled_us as f64 / 1e6 } else { measured },
            input_bytes: out.input_bytes,
            output_bytes: out_bytes,
        });
        counters.merge(&out.counters);
        output.push(out.records);
    }

    // Reducers pull their input through the shuffle — charged below at the
    // fetch tiers — so their plan carries no input bytes and no locality
    // preference.
    let reduce_specs: Vec<TaskSpec> = reduce_costs
        .iter()
        .map(|c| TaskSpec {
            cost: TaskCost { input_bytes: 0, ..*c },
            hosts: Vec::new(),
        })
        .collect();
    let reduce_plan = cluster.plan_phase(&reduce_specs);
    check_plan(&reduce_plan, "reduce", &job.name)?;
    absorb_plan(&mut counters, &reduce_plan, false, cluster.total_slots());

    // The signature Hadoop failure case: a reduce fetch that targets a map
    // output on a slave that has since died fails (`FETCH_FAILURES`), and
    // the completed map is re-executed on a live node (`MAP_RERUNS`) so
    // the fetch can be re-planned against its new home. Repeat until every
    // fetch source is alive (deaths during a rerun can strike again).
    let mut map_slaves = map_plan.winning_slaves(nmaps);
    let mut rerun_makespan_s = 0.0f64;
    let mut rerun_traces: Vec<trace::PlanTrace> = Vec::new();
    loop {
        let dead = cluster.faults().dead();
        let lost: Vec<usize> = (0..nmaps)
            .filter(|&mi| {
                map_slaves[mi].is_some_and(|s| dead.get(s).copied().unwrap_or(false))
                    && seg_bytes[mi].iter().any(|&b| b > 0)
            })
            .collect();
        if lost.is_empty() {
            break;
        }
        for &mi in &lost {
            let failed_fetches =
                seg_bytes[mi].iter().filter(|&&b| b > 0).count() as u64;
            counters.incr(names::FETCH_FAILURES, failed_fetches);
            // The lost output's home no longer counts as a fetch source.
            map_slaves[mi] = None;
        }
        counters.incr(names::MAP_RERUNS, lost.len() as u64);
        let rerun_specs: Vec<TaskSpec> =
            lost.iter().map(|&mi| map_specs[mi].clone()).collect();
        let rerun_plan = cluster.plan_phase(&rerun_specs);
        check_plan(&rerun_plan, "map re-execution", &job.name)?;
        absorb_plan(&mut counters, &rerun_plan, true, cluster.total_slots());
        let rerun_slaves = rerun_plan.winning_slaves(lost.len());
        for (i, &mi) in lost.iter().enumerate() {
            map_slaves[mi] = rerun_slaves[i];
        }
        rerun_makespan_s += rerun_plan.makespan_s;
        if cluster.trace().enabled() {
            rerun_traces.push(trace::plan_trace(
                &rerun_plan,
                &rerun_specs,
                cluster.model(),
            ));
        }
    }

    // Charge every segment fetch at the locality tier between the map
    // attempt that produced it (or its re-execution) and the reduce
    // attempt that consumes it.
    let reduce_slaves = reduce_plan.winning_slaves(reduce_costs.len());
    let fetch = shuffle::plan_fetches(
        cluster.topology(),
        cluster.model(),
        &map_slaves,
        &reduce_slaves,
        &seg_bytes,
        shuffle_cfg.parallelism(),
    );
    counters.incr(names::SHUFFLE_FETCH_BYTES_LOCAL, fetch.bytes_node_local);
    counters.incr(names::SHUFFLE_FETCH_BYTES_RACK, fetch.bytes_rack_local);
    counters.incr(names::SHUFFLE_FETCH_BYTES_REMOTE, fetch.bytes_off_rack);
    counters.incr(
        names::SHUFFLE_FETCH_US,
        (fetch.total_fetch_s * 1e6).round() as u64,
    );

    let virtual_time_s = cluster.planned_job_time_with_fetch(
        &map_plan,
        &reduce_plan,
        fetch.fetch_s,
    ) + rerun_makespan_s;
    if cluster.trace().enabled() {
        cluster.trace().record_job(trace::JobTrace {
            name: job.name.clone(),
            overhead_s: cluster.model().job_overhead(cluster.num_slaves()),
            virtual_time_s,
            map: trace::plan_trace(&map_plan, &map_specs, cluster.model()),
            reruns: rerun_traces,
            fetch: Some(trace::FetchTrace {
                fetch_s: fetch.fetch_s,
                reducers: fetch.reducers.clone(),
            }),
            reduce: Some(trace::plan_trace(
                &reduce_plan,
                &reduce_specs,
                cluster.model(),
            )),
            spill_bytes: seg_bytes
                .iter()
                .map(|row| row.iter().sum::<u64>())
                .collect(),
        });
    }

    let stats = JobStats {
        // Lost-output re-executions extend the job's critical path: the
        // affected reducers wait for the reruns before their final fetch.
        virtual_time_s,
        wall_time_s: wall_start.elapsed().as_secs_f64(),
        map_costs,
        reduce_costs,
        shuffle_bytes,
        spilled_records: counters.get(names::SPILLED_RECORDS),
        merge_passes: counters.get(names::MERGE_PASSES),
        shuffle_fetch_s: fetch.fetch_s,
    };
    Ok(JobResult { output, counters, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::job::JobBuilder;
    use crate::mapreduce::shuffle::ShuffleConfig;
    use crate::mapreduce::types::{FnMapper, FnReducer, Values};
    use crate::util::bytes::{decode_u64, encode_u64};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn word_splits() -> Vec<Vec<KV>> {
        // Two splits of words.
        vec![
            vec![
                (vec![], b"the quick brown fox".to_vec()),
                (vec![], b"the lazy dog".to_vec()),
            ],
            vec![(vec![], b"the fox jumps over the dog".to_vec())],
        ]
    }

    fn wordcount_job(input: Vec<Vec<KV>>, with_combiner: bool) -> Job {
        let mapper = Arc::new(FnMapper(|_k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            for w in std::str::from_utf8(v).unwrap().split_whitespace() {
                ctx.emit(w.as_bytes().to_vec(), encode_u64(1).to_vec());
            }
            Ok(())
        }));
        let sum = Arc::new(FnReducer(
            |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
                let mut total = 0u64;
                while let Some(v) = vs.next_value() {
                    total += decode_u64(v);
                }
                ctx.emit(k.to_vec(), encode_u64(total).to_vec());
                Ok(())
            },
        ));
        let mut b = JobBuilder::new("wordcount", input, mapper).reducer(sum.clone(), 3);
        if with_combiner {
            b = b.combiner(sum);
        }
        b.build()
    }

    fn counts_of(result: &mut JobResult) -> std::collections::HashMap<String, u64> {
        result
            .sorted_records()
            .into_iter()
            .map(|(k, v)| (String::from_utf8(k).unwrap(), decode_u64(&v)))
            .collect()
    }

    #[test]
    fn wordcount_end_to_end() {
        let cluster = Cluster::new(4);
        let job = wordcount_job(word_splits(), false);
        let mut result = run(&cluster, &job).unwrap();
        let counts = counts_of(&mut result);
        assert_eq!(counts["the"], 4);
        assert_eq!(counts["fox"], 2);
        assert_eq!(counts["dog"], 2);
        assert_eq!(counts["quick"], 1);
        assert_eq!(result.counters.get(names::MAP_INPUT_RECORDS), 3);
        assert!(result.stats.virtual_time_s > 0.0);
    }

    #[test]
    fn combiner_reduces_shuffle_but_not_answer() {
        let cluster = Cluster::new(2);
        let mut plain = run(&cluster, &wordcount_job(word_splits(), false)).unwrap();
        let mut combined = run(&cluster, &wordcount_job(word_splits(), true)).unwrap();
        assert_eq!(counts_of(&mut plain), counts_of(&mut combined));
        assert!(
            combined.stats.shuffle_bytes < plain.stats.shuffle_bytes,
            "combiner should shrink shuffle: {} vs {}",
            combined.stats.shuffle_bytes,
            plain.stats.shuffle_bytes
        );
    }

    #[test]
    fn map_only_job_returns_per_task_output() {
        let cluster = Cluster::new(2);
        let mapper = Arc::new(FnMapper(|k: &[u8], _v: &[u8], ctx: &mut TaskContext| {
            ctx.emit(k.to_vec(), b"x".to_vec());
            Ok(())
        }));
        let input = vec![
            vec![(vec![1], vec![]), (vec![2], vec![])],
            vec![(vec![3], vec![])],
        ];
        let job = JobBuilder::new("maponly", input, mapper).build();
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.output.len(), 2); // one per map task
        assert_eq!(r.output[0].len(), 2);
        assert_eq!(r.output[1].len(), 1);
        assert_eq!(r.stats.shuffle_bytes, 0);
        assert_eq!(r.counters.get(names::SPILLED_RECORDS), 0);
    }

    #[test]
    fn map_only_job_still_runs_its_combiner() {
        let cluster = Cluster::new(2);
        let mapper = Arc::new(FnMapper(|_k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            for w in std::str::from_utf8(v).unwrap().split_whitespace() {
                ctx.emit(w.as_bytes().to_vec(), encode_u64(1).to_vec());
            }
            Ok(())
        }));
        let sum = Arc::new(FnReducer(
            |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
                let mut total = 0u64;
                while let Some(v) = vs.next_value() {
                    total += decode_u64(v);
                }
                ctx.emit(k.to_vec(), encode_u64(total).to_vec());
                Ok(())
            },
        ));
        let input = vec![vec![(vec![], b"a b a a b".to_vec())]];
        let job = JobBuilder::new("maponly-combine", input, mapper)
            .combiner(sum)
            .build();
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.counters.get(names::MAP_OUTPUT_RECORDS), 5);
        assert_eq!(r.counters.get(names::COMBINE_OUTPUT_RECORDS), 2);
        // Output is the combined, key-sorted task output.
        assert_eq!(r.output.len(), 1);
        let recs = &r.output[0];
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].0, b"a".to_vec());
        assert_eq!(decode_u64(&recs[0].1), 3);
        assert_eq!(recs[1].0, b"b".to_vec());
        assert_eq!(decode_u64(&recs[1].1), 2);
    }

    #[test]
    fn injected_attempt_failures_replan_without_changing_the_answer() {
        // Virtual attempt failures (the cluster failure domain) re-plan
        // tasks on fresh heartbeats; job output must be byte-identical to
        // the fault-free run for EVERY chaos seed, and across the seed
        // sweep some attempts must actually have failed.
        let mut clean = run(&Cluster::new(3), &wordcount_job(word_splits(), false)).unwrap();
        let clean_counts = counts_of(&mut clean);
        let mut total_failed = 0u64;
        for seed in 1..=8u64 {
            let mut cluster = Cluster::new(3);
            cluster.set_fault_config(crate::cluster::FaultConfig {
                task_fail_prob: 0.4,
                seed,
                max_attempts: 20,
                blacklist_after: 1000,
                ..crate::cluster::FaultConfig::default()
            });
            let mut faulty = run(&cluster, &wordcount_job(word_splits(), false)).unwrap();
            assert_eq!(clean_counts, counts_of(&mut faulty), "seed {seed}");
            let failed = faulty.counters.get(names::FAILED_MAP_ATTEMPTS)
                + faulty.counters.get(names::FAILED_REDUCE_ATTEMPTS);
            if failed > 0 {
                assert!(
                    faulty.stats.virtual_time_s > clean.stats.virtual_time_s,
                    "seed {seed}: re-planned attempts must cost virtual time"
                );
            }
            total_failed += failed;
        }
        assert!(total_failed > 0, "p=0.4 over 8 seeds must fail some attempts");
    }

    #[test]
    fn permanently_failing_task_fails_the_job_after_max_attempts() {
        let cluster = Cluster::new(2); // default faults: max_attempts = 4
        let mapper = Arc::new(FnMapper(|k: &[u8], _v: &[u8], _ctx: &mut TaskContext| {
            if k == [1] {
                Err(Error::MapReduce("poisoned split".into()))
            } else {
                Ok(())
            }
        }));
        let job = JobBuilder::new(
            "doomed",
            vec![vec![(vec![0], vec![])], vec![(vec![1], vec![])]],
            mapper,
        )
        .build();
        let err = run(&cluster, &job).unwrap_err();
        assert!(err.to_string().contains("failed after 4 attempts"), "{err}");
    }

    #[test]
    fn real_task_error_reexecuted_on_a_fresh_round() {
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let cluster = Cluster::new(1);
        let mapper = Arc::new(FnMapper(|_k: &[u8], _v: &[u8], _ctx: &mut TaskContext| {
            // First invocation errors, later ones succeed.
            if CALLS.fetch_add(1, Ordering::SeqCst) == 0 {
                Err(Error::MapReduce("flaky".into()))
            } else {
                Ok(())
            }
        }));
        let job = JobBuilder::new("flaky", vec![vec![(vec![], vec![])]], mapper).build();
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.counters.get(names::FAILED_MAP_ATTEMPTS), 1);
    }

    #[test]
    fn failed_task_does_not_discard_completed_siblings() {
        // The partial-results fix: split 0's mapper fails once, split 1's
        // succeeds on round one and must be computed exactly once.
        static SPLIT0_CALLS: AtomicUsize = AtomicUsize::new(0);
        static SPLIT1_CALLS: AtomicUsize = AtomicUsize::new(0);
        let cluster = Cluster::new(2);
        let mapper = Arc::new(FnMapper(|k: &[u8], _v: &[u8], ctx: &mut TaskContext| {
            if k == [0] {
                if SPLIT0_CALLS.fetch_add(1, Ordering::SeqCst) == 0 {
                    return Err(Error::MapReduce("flaky".into()));
                }
            } else {
                SPLIT1_CALLS.fetch_add(1, Ordering::SeqCst);
            }
            ctx.emit(k.to_vec(), vec![]);
            Ok(())
        }));
        let job = JobBuilder::new(
            "partial",
            vec![vec![(vec![0], vec![])], vec![(vec![1], vec![])]],
            mapper,
        )
        .build();
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.output.len(), 2);
        assert_eq!(SPLIT1_CALLS.load(Ordering::SeqCst), 1, "sibling reused, not rerun");
        assert_eq!(SPLIT0_CALLS.load(Ordering::SeqCst), 2, "failed task re-executed");
    }

    #[test]
    fn node_death_triggers_map_rerun_and_fetch_failures() {
        // 2 slaves; slave 1 dies during the reduce phase: the map outputs
        // it held must be re-executed on slave 0 and every fetch that
        // targeted them charged as failed.
        let mut cluster = Cluster::new(2);
        cluster.set_fault_config(crate::cluster::FaultConfig {
            node_deaths: vec![crate::cluster::NodeDeath { slave: 1, at_heartbeat: 7 }],
            ..crate::cluster::FaultConfig::default()
        });
        // 6 splits spread over both slaves' 4 slots.
        let splits: Vec<Vec<KV>> = (0..6)
            .map(|i| vec![(vec![], format!("word{} word{} shared", i, i).into_bytes())])
            .collect();
        let clean = run(&Cluster::new(2), &wordcount_job(splits.clone(), false)).unwrap();
        let mut r = run(&cluster, &wordcount_job(splits, false)).unwrap();
        assert_eq!(r.counters.get(names::NODE_DEATHS), 1);
        assert!(
            r.counters.get(names::MAP_RERUNS) > 0,
            "lost map outputs must re-execute: {:?}",
            r.counters
        );
        assert!(r.counters.get(names::FETCH_FAILURES) > 0);
        // Output identical to the fault-free run.
        let mut clean = clean;
        assert_eq!(counts_of(&mut clean), counts_of(&mut r));
        assert!(r.stats.virtual_time_s > clean.stats.virtual_time_s);
    }

    #[test]
    fn reduce_outputs_sorted_within_partition() {
        let cluster = Cluster::new(2);
        let job = wordcount_job(word_splits(), false);
        let r = run(&cluster, &job).unwrap();
        for part in &r.output {
            for w in part.windows(2) {
                assert!(w[0].0 <= w[1].0, "partition not sorted");
            }
        }
    }

    #[test]
    fn every_emitted_key_lands_in_exactly_one_partition() {
        // Routing invariant: reducers together see every mapped record once.
        let cluster = Cluster::new(3);
        let job = wordcount_job(word_splits(), false);
        let mut r = run(&cluster, &job).unwrap();
        let total: u64 = counts_of(&mut r).values().sum();
        assert_eq!(total, 13, "13 words in the corpus");
    }

    #[test]
    fn split_hosts_flow_into_locality_counters() {
        let mut cluster =
            Cluster::with_model(2, 2, crate::cluster::NetworkModel::default());
        cluster.set_topology(crate::scheduler::RackTopology::uniform(2, 2));
        let mut job = wordcount_job(word_splits(), false);
        job.split_hosts = vec![vec![0], vec![1]];
        let r = run(&cluster, &job).unwrap();
        let placed = r.counters.get(names::DATA_LOCAL_MAPS)
            + r.counters.get(names::RACK_LOCAL_MAPS)
            + r.counters.get(names::OFF_RACK_MAPS);
        assert_eq!(placed, 2, "both located splits must be tallied");
        assert!(r.counters.get(names::HEARTBEATS) > 0);
        // The default locality-first policy finds both node-local homes.
        assert_eq!(r.counters.get(names::DATA_LOCAL_MAPS), 2);
    }

    #[test]
    fn jobs_without_hosts_stay_out_of_locality_tallies() {
        let cluster = Cluster::new(2);
        let job = wordcount_job(word_splits(), false);
        let r = run(&cluster, &job).unwrap();
        assert_eq!(r.counters.get(names::DATA_LOCAL_MAPS), 0);
        assert_eq!(r.counters.get(names::RACK_LOCAL_MAPS), 0);
        assert_eq!(r.counters.get(names::OFF_RACK_MAPS), 0);
    }

    #[test]
    fn spill_counters_cover_every_record_with_tiny_buffer() {
        let cluster = Cluster::new(2);
        let mut job = wordcount_job(word_splits(), false);
        job.shuffle = Some(ShuffleConfig {
            sort_buffer_kb: 0, // floor: spill on every record
            merge_factor: 2,
            fetch_parallelism: 1,
        });
        let mut r = run(&cluster, &job).unwrap();
        let map_out = r.counters.get(names::MAP_OUTPUT_RECORDS);
        let spilled = r.counters.get(names::SPILLED_RECORDS);
        assert_eq!(map_out, 13);
        assert!(
            spilled >= map_out,
            "tiny buffer must spill every record: {spilled} < {map_out}"
        );
        assert!(r.counters.get(names::SPILLS) >= 13);
        assert!(r.counters.get(names::MERGE_PASSES) > 0);
        assert_eq!(counts_of(&mut r)["the"], 4, "spilling must not change results");
    }

    #[test]
    fn one_spill_when_buffer_is_large() {
        let cluster = Cluster::new(2);
        let job = wordcount_job(word_splits(), false); // default 512 KiB buffer
        let r = run(&cluster, &job).unwrap();
        assert_eq!(
            r.counters.get(names::SPILLS),
            2,
            "one spill per map task with a roomy buffer"
        );
        assert_eq!(
            r.counters.get(names::SPILLED_RECORDS),
            r.counters.get(names::MAP_OUTPUT_RECORDS)
        );
    }

    #[test]
    fn fetch_counters_account_every_shuffled_byte() {
        let mut cluster =
            Cluster::with_model(4, 2, crate::cluster::NetworkModel::default());
        cluster.set_topology(crate::scheduler::RackTopology::uniform(4, 2));
        let job = wordcount_job(word_splits(), false);
        let r = run(&cluster, &job).unwrap();
        let fetched = r.counters.get(names::SHUFFLE_FETCH_BYTES_LOCAL)
            + r.counters.get(names::SHUFFLE_FETCH_BYTES_RACK)
            + r.counters.get(names::SHUFFLE_FETCH_BYTES_REMOTE);
        assert_eq!(
            fetched,
            r.stats.shuffle_bytes,
            "every shuffled byte must be charged at some tier"
        );
        assert!(r.stats.shuffle_fetch_s > 0.0);
        assert!(r.counters.get(names::SHUFFLE_FETCH_US) > 0);
    }

    #[test]
    fn shuffle_knobs_do_not_change_the_answer() {
        let cluster = Cluster::new(3);
        let mut base = run(&cluster, &wordcount_job(word_splits(), false)).unwrap();
        let expected = counts_of(&mut base);
        for (kb, factor) in [(0usize, 2usize), (0, 16), (1 << 14, 2), (1 << 14, 16)] {
            for with_combiner in [false, true] {
                let mut job = wordcount_job(word_splits(), with_combiner);
                job.shuffle = Some(ShuffleConfig {
                    sort_buffer_kb: kb,
                    merge_factor: factor,
                    fetch_parallelism: 3,
                });
                let mut r = run(&cluster, &job).unwrap();
                assert_eq!(
                    counts_of(&mut r),
                    expected,
                    "kb={kb} factor={factor} combiner={with_combiner}"
                );
            }
        }
    }

    #[test]
    fn reducer_sees_values_as_a_stream_not_a_vec() {
        // A reducer that counts how many values it can pull lazily; with 3
        // splits each emitting the same key, all values arrive in one group.
        let cluster = Cluster::new(2);
        let mapper = Arc::new(FnMapper(|_k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            ctx.emit(b"key".to_vec(), v.to_vec());
            Ok(())
        }));
        let reducer = Arc::new(FnReducer(
            |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
                let mut n: u64 = 0;
                let mut bytes: u64 = 0;
                while let Some(v) = vs.next_value() {
                    n += 1;
                    bytes += v.len() as u64;
                }
                ctx.emit(k.to_vec(), encode_u64(n * 1000 + bytes).to_vec());
                Ok(())
            },
        ));
        let input: Vec<Vec<KV>> = (0..3)
            .map(|i| vec![(vec![], vec![i as u8; (i + 1) as usize])])
            .collect();
        let job = JobBuilder::new("stream", input, mapper)
            .reducer(reducer, 2)
            .build();
        let mut r = run(&cluster, &job).unwrap();
        let recs = r.sorted_records();
        assert_eq!(recs.len(), 1);
        // 3 values totalling 1+2+3 = 6 bytes.
        assert_eq!(decode_u64(&recs[0].1), 3 * 1000 + 6);
        assert_eq!(r.counters.get(names::REDUCE_INPUT_GROUPS), 1);
    }

    #[test]
    fn values_never_pulled_still_advances_groups() {
        // A reducer that ignores its values entirely: every group must
        // still be visited exactly once.
        let cluster = Cluster::new(2);
        let job_input = word_splits();
        let mapper = Arc::new(FnMapper(|_k: &[u8], v: &[u8], ctx: &mut TaskContext| {
            for w in std::str::from_utf8(v).unwrap().split_whitespace() {
                ctx.emit(w.as_bytes().to_vec(), vec![1]);
            }
            Ok(())
        }));
        let reducer = Arc::new(FnReducer(
            |k: &[u8], _vs: &mut dyn Values, ctx: &mut TaskContext| {
                ctx.emit(k.to_vec(), vec![]);
                Ok(())
            },
        ));
        let job = JobBuilder::new("lazy", job_input, mapper)
            .reducer(reducer, 2)
            .build();
        let mut r = run(&cluster, &job).unwrap();
        // 8 distinct words in the corpus.
        assert_eq!(r.sorted_records().len(), 8);
        assert_eq!(r.counters.get(names::REDUCE_INPUT_GROUPS), 8);
    }
}
