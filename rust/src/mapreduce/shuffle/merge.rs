//! Sorted segments, k-way merging and the streaming group iterator.
//!
//! A [`Segment`] is one sorted run of intermediate records — the unit the
//! map side spills and the reduce side fetches. [`merge_records`] k-way
//! merges runs into one; [`merge_to_factor`] applies the `io.sort.factor`
//! discipline (merge in passes until at most `factor` runs remain);
//! [`GroupedMerge`] streams the final merge one key group at a time into
//! the reducer without ever materializing a partition.

use super::super::types::{Bytes, Values, KV};

/// One sorted run of intermediate records for a single reduce partition.
#[derive(Debug, Clone, Default)]
pub struct Segment {
    records: Vec<KV>,
}

impl Segment {
    /// Wrap records already sorted by key (debug-asserted).
    pub fn from_sorted(records: Vec<KV>) -> Self {
        debug_assert!(
            records.windows(2).all(|w| w[0].0 <= w[1].0),
            "segment records must be key-sorted"
        );
        Self { records }
    }

    /// Sort records by key (unstable — ties keep arbitrary value order)
    /// and wrap them.
    pub fn from_unsorted(mut records: Vec<KV>) -> Self {
        records.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        Self { records }
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when the segment holds no records.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Key of record `i`.
    pub fn key(&self, i: usize) -> &[u8] {
        &self.records[i].0
    }

    /// Value of record `i`.
    pub fn value(&self, i: usize) -> &[u8] {
        &self.records[i].1
    }

    /// Total key+value bytes (what a fetch of this segment moves).
    pub fn bytes(&self) -> u64 {
        self.records
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum()
    }

    /// Consume into the raw record vector.
    pub fn into_records(self) -> Vec<KV> {
        self.records
    }
}

/// K-way merge sorted runs into one sorted run.
///
/// Ties break on the lower segment index, so the output is deterministic
/// in the segments' submission order (map-task order on the reduce side).
pub fn merge_records(segs: Vec<Segment>) -> Segment {
    let total: usize = segs.iter().map(|s| s.len()).sum();
    // Reversed stacks: `last()` peeks the smallest remaining record and
    // `pop()` moves it out without cloning.
    let mut stacks: Vec<Vec<KV>> = segs
        .into_iter()
        .map(|s| {
            let mut r = s.into_records();
            r.reverse();
            r
        })
        .collect();
    let mut out: Vec<KV> = Vec::with_capacity(total);
    loop {
        let mut best: Option<usize> = None;
        for (i, stack) in stacks.iter().enumerate() {
            if let Some((key, _)) = stack.last() {
                best = match best {
                    Some(b) if stacks[b].last().unwrap().0 <= *key => Some(b),
                    _ => Some(i),
                };
            }
        }
        match best {
            Some(i) => out.push(stacks[i].pop().unwrap()),
            None => break,
        }
    }
    Segment::from_sorted(out)
}

/// Merge runs in passes of at most `factor` until no more than `factor`
/// remain (Hadoop's intermediate on-disk merges). Empty runs are dropped.
///
/// Returns `(remaining runs, merge passes, records rewritten)` — rewritten
/// records are re-spills and count into `SPILLED_RECORDS`.
pub fn merge_to_factor(
    mut segs: Vec<Segment>,
    factor: usize,
) -> (Vec<Segment>, u64, u64) {
    let factor = factor.max(2);
    segs.retain(|s| !s.is_empty());
    let mut passes = 0u64;
    let mut rewritten = 0u64;
    while segs.len() > factor {
        // Hadoop's Merger discipline: a minimal first pass brings the run
        // count to ≡ 1 (mod factor−1), so every later pass merges exactly
        // `factor` runs and rewrites as little data as possible.
        let first = (segs.len() - 1) % (factor - 1) + 1;
        let take = if first > 1 { first } else { factor };
        let group: Vec<Segment> = segs.drain(..take).collect();
        let merged = merge_records(group);
        passes += 1;
        rewritten += merged.len() as u64;
        segs.push(merged);
    }
    (segs, passes, rewritten)
}

/// Streaming grouped merge over at most `merge_factor` sorted runs: yields
/// one key group at a time; the group's values are pulled lazily through
/// [`ValueStream`], so no partition (or group) is ever materialized.
pub struct GroupedMerge<'s> {
    segments: &'s [Segment],
    cursors: Vec<usize>,
    current: Option<Bytes>,
}

impl<'s> GroupedMerge<'s> {
    /// Stream over the given sorted runs.
    pub fn new(segments: &'s [Segment]) -> Self {
        Self {
            cursors: vec![0; segments.len()],
            segments,
            current: None,
        }
    }

    /// Advance past the previous group (whether or not the reducer drained
    /// it) and return the next smallest key, or `None` when exhausted.
    pub fn next_key(&mut self) -> Option<Bytes> {
        if let Some(prev) = self.current.take() {
            for (s, seg) in self.segments.iter().enumerate() {
                let mut c = self.cursors[s];
                while c < seg.len() && seg.key(c) == prev.as_slice() {
                    c += 1;
                }
                self.cursors[s] = c;
            }
        }
        let mut min: Option<&[u8]> = None;
        for (s, seg) in self.segments.iter().enumerate() {
            let c = self.cursors[s];
            if c < seg.len() {
                let k = seg.key(c);
                min = match min {
                    Some(m) if m <= k => Some(m),
                    _ => Some(k),
                };
            }
        }
        let key = min.map(|k| k.to_vec());
        self.current = key.clone();
        key
    }

    /// The value stream of the current group (call after [`Self::next_key`]
    /// returned `Some`).
    pub fn values(&mut self) -> ValueStream<'_> {
        ValueStream {
            segments: self.segments,
            cursors: &mut self.cursors,
            key: self.current.as_deref().expect("values() before next_key()"),
        }
    }
}

/// Lazy per-group value stream: pulls the current key's values segment by
/// segment, advancing the merge cursors as it goes.
pub struct ValueStream<'a> {
    segments: &'a [Segment],
    cursors: &'a mut Vec<usize>,
    key: &'a [u8],
}

impl Values for ValueStream<'_> {
    fn next_value(&mut self) -> Option<&[u8]> {
        for (s, seg) in self.segments.iter().enumerate() {
            let c = self.cursors[s];
            if c < seg.len() && seg.key(c) == self.key {
                self.cursors[s] = c + 1;
                return Some(seg.value(c));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(k: u8, v: u8) -> KV {
        (vec![k], vec![v])
    }

    fn seg(pairs: &[(u8, u8)]) -> Segment {
        Segment::from_sorted(pairs.iter().map(|&(k, v)| kv(k, v)).collect())
    }

    #[test]
    fn merge_interleaves_and_breaks_ties_by_segment_order() {
        let a = seg(&[(1, 10), (3, 30), (5, 50)]);
        let b = seg(&[(1, 11), (2, 20), (5, 51)]);
        let m = merge_records(vec![a, b]);
        let keys: Vec<u8> = (0..m.len()).map(|i| m.key(i)[0]).collect();
        assert_eq!(keys, vec![1, 1, 2, 3, 5, 5]);
        // Tie on key 1: segment 0's record first.
        assert_eq!(m.value(0), &[10]);
        assert_eq!(m.value(1), &[11]);
    }

    #[test]
    fn merge_to_factor_respects_factor_and_counts_passes() {
        let runs: Vec<Segment> =
            (0..7).map(|i| seg(&[(i as u8, i as u8)])).collect();
        let (out, passes, rewritten) = merge_to_factor(runs, 3);
        assert!(out.len() <= 3, "got {} runs", out.len());
        assert!(passes >= 1);
        assert!(rewritten >= 3);
        let total: usize = out.iter().map(|s| s.len()).sum();
        assert_eq!(total, 7, "no records lost");
    }

    #[test]
    fn merge_to_factor_first_pass_is_minimal() {
        // Hadoop's io.sort.factor discipline: 11 runs at factor 10 merge
        // just 2 runs (not 10) — one small pass reaches the bound.
        let runs: Vec<Segment> =
            (0..11).map(|i| seg(&[(i as u8, 0)])).collect();
        let (out, passes, rewritten) = merge_to_factor(runs, 10);
        assert_eq!(out.len(), 10);
        assert_eq!(passes, 1);
        assert_eq!(rewritten, 2, "minimal first pass rewrites 2 records");
    }

    #[test]
    fn merge_to_factor_noop_when_few_runs() {
        let runs = vec![seg(&[(1, 1)]), seg(&[(2, 2)])];
        let (out, passes, rewritten) = merge_to_factor(runs, 10);
        assert_eq!(out.len(), 2);
        assert_eq!(passes, 0);
        assert_eq!(rewritten, 0);
    }

    #[test]
    fn grouped_merge_streams_groups_in_key_order() {
        let a = seg(&[(1, 10), (2, 20), (2, 21)]);
        let b = seg(&[(2, 22), (3, 30)]);
        let segs = vec![a, b];
        let mut gm = GroupedMerge::new(&segs);
        let mut seen: Vec<(u8, Vec<u8>)> = Vec::new();
        while let Some(key) = gm.next_key() {
            let mut vals = Vec::new();
            let mut vs = gm.values();
            while let Some(v) = vs.next_value() {
                vals.push(v[0]);
            }
            seen.push((key[0], vals));
        }
        assert_eq!(
            seen,
            vec![
                (1, vec![10]),
                (2, vec![20, 21, 22]),
                (3, vec![30]),
            ]
        );
    }

    #[test]
    fn undrained_group_is_skipped() {
        let segs = vec![seg(&[(1, 10), (1, 11), (2, 20)])];
        let mut gm = GroupedMerge::new(&segs);
        let k1 = gm.next_key().unwrap();
        assert_eq!(k1, vec![1]);
        // Reducer never pulls the values; the merge must still advance.
        let k2 = gm.next_key().unwrap();
        assert_eq!(k2, vec![2]);
        assert!(gm.next_key().is_none());
    }

    #[test]
    fn empty_input_yields_nothing() {
        let segs: Vec<Segment> = Vec::new();
        let mut gm = GroupedMerge::new(&segs);
        assert!(gm.next_key().is_none());
        let m = merge_records(Vec::new());
        assert!(m.is_empty());
    }

    #[test]
    fn segment_bytes_counts_keys_and_values() {
        let s = seg(&[(1, 1), (2, 2)]);
        assert_eq!(s.bytes(), 4);
        assert_eq!(Segment::default().bytes(), 0);
    }

    #[test]
    fn from_unsorted_sorts_by_key() {
        let s = Segment::from_unsorted(vec![kv(3, 0), kv(1, 0), kv(2, 0)]);
        let keys: Vec<u8> = (0..s.len()).map(|i| s.key(i)[0]).collect();
        assert_eq!(keys, vec![1, 2, 3]);
    }
}
