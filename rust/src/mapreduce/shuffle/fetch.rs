//! Reduce-side fetch planning: charge every segment copy at the locality
//! tier between the map attempt that produced it and the reduce attempt
//! that consumes it.
//!
//! Hadoop reducers pull map outputs over HTTP with a bounded number of
//! parallel copier threads. Here the JobTracker's winning attempts pin
//! each map output and each reduce task to a slave; a fetch between them
//! is node-local (same slave: local disk), rack-local (same rack: bounded
//! by the top-of-rack switch) or off-rack (the oversubscribed core link),
//! priced through [`NetworkModel::read_time_at`] — the same tiers map
//! input reads pay.

use crate::cluster::NetworkModel;
use crate::scheduler::{classify, Locality, RackTopology};

/// One reducer's share of the fetch phase, indexed by reduce task id.
/// Reducers whose every segment was empty keep the zero default.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReducerFetch {
    /// Virtual seconds this reducer spent fetching (streams + waves).
    pub fetch_s: f64,
    /// Non-empty segment copies this reducer performed.
    pub fetches: u64,
    /// Bytes this reducer pulled across all tiers.
    pub bytes: u64,
}

/// The virtual cost and locality mix of one job's shuffle fetches.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FetchPlan {
    /// Bytes fetched from the reducer's own node.
    pub bytes_node_local: u64,
    /// Bytes fetched from another node in the reducer's rack.
    pub bytes_rack_local: u64,
    /// Bytes fetched across racks.
    pub bytes_off_rack: u64,
    /// Segment fetches performed (non-empty segments only).
    pub fetches: u64,
    /// Virtual seconds of the slowest reducer's fetch phase — the shuffle
    /// barrier the job's makespan pays.
    pub fetch_s: f64,
    /// Sum of every reducer's fetch seconds (serial work, for reporting).
    pub total_fetch_s: f64,
    /// Per-reducer breakdown, indexed by reduce task id.
    pub reducers: Vec<ReducerFetch>,
}

impl FetchPlan {
    /// All bytes crossing the shuffle, every tier.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_node_local + self.bytes_rack_local + self.bytes_off_rack
    }
}

/// Plan the fetch phase of one job.
///
/// `map_slaves[m]` / `reduce_slaves[r]` are the winning-attempt slaves
/// from the phase plans (`None` falls back to node-local — nothing to
/// charge without a placement). `seg_bytes[m][r]` is the size of map
/// `m`'s segment for partition `r`; zero-byte segments are skipped (an
/// empty map output is never copied). `parallelism` bounds the concurrent
/// copy streams per reducer; each wave of copies pays one
/// `shuffle_latency_s` of connection setup.
pub fn plan_fetches(
    topo: &RackTopology,
    model: &NetworkModel,
    map_slaves: &[Option<usize>],
    reduce_slaves: &[Option<usize>],
    seg_bytes: &[Vec<u64>],
    parallelism: usize,
) -> FetchPlan {
    let p = parallelism.max(1);
    let mut plan = FetchPlan::default();
    for (r, &red_slave) in reduce_slaves.iter().enumerate() {
        let mut serial_s = 0.0f64;
        let mut fetches = 0u64;
        let mut reducer_bytes = 0u64;
        for (m, &map_slave) in map_slaves.iter().enumerate() {
            let bytes = seg_bytes.get(m).and_then(|row| row.get(r)).copied().unwrap_or(0);
            if bytes == 0 {
                continue;
            }
            let tier = match (map_slave, red_slave) {
                (Some(src), Some(dst)) => classify(dst, &[src], topo),
                _ => Locality::NodeLocal,
            };
            match tier {
                Locality::NodeLocal => plan.bytes_node_local += bytes,
                Locality::RackLocal => plan.bytes_rack_local += bytes,
                Locality::OffRack => plan.bytes_off_rack += bytes,
            }
            serial_s += model.read_time_at(bytes, tier);
            fetches += 1;
            reducer_bytes += bytes;
        }
        if fetches == 0 {
            plan.reducers.push(ReducerFetch::default());
            continue;
        }
        plan.fetches += fetches;
        let streams = p.min(fetches as usize).max(1);
        let waves = fetches.div_ceil(streams as u64);
        let reducer_s =
            serial_s / streams as f64 + model.shuffle_latency_s * waves as f64;
        plan.total_fetch_s += reducer_s;
        plan.fetch_s = plan.fetch_s.max(reducer_s);
        plan.reducers.push(ReducerFetch {
            fetch_s: reducer_s,
            fetches,
            bytes: reducer_bytes,
        });
    }
    debug_assert_eq!(plan.reducers.len(), reduce_slaves.len());
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> NetworkModel {
        NetworkModel {
            disk_bw: 100e6,
            rack_bw: 50e6,
            cross_rack_bw: 10e6,
            shuffle_latency_s: 0.0,
            ..NetworkModel::default()
        }
    }

    #[test]
    fn tiers_follow_the_topology() {
        // 4 slaves in 2 racks: [0,1 | 2,3].
        let topo = RackTopology::uniform(4, 2);
        let m = model();
        // One map on each of slaves 0, 1, 2; reducer on slave 0.
        let map_slaves = [Some(0), Some(1), Some(2)];
        let reduce_slaves = [Some(0)];
        let seg = vec![vec![1000u64], vec![1000], vec![1000]];
        let plan = plan_fetches(&topo, &m, &map_slaves, &reduce_slaves, &seg, 4);
        assert_eq!(plan.bytes_node_local, 1000);
        assert_eq!(plan.bytes_rack_local, 1000);
        assert_eq!(plan.bytes_off_rack, 1000);
        assert_eq!(plan.fetches, 3);
        assert_eq!(plan.total_bytes(), 3000);
        assert!(plan.fetch_s > 0.0);
    }

    #[test]
    fn off_rack_fetches_cost_more() {
        let topo = RackTopology::uniform(2, 2); // one slave per rack
        let m = model();
        let bytes = vec![vec![100_000_000u64]];
        let local =
            plan_fetches(&topo, &m, &[Some(0)], &[Some(0)], &bytes, 1);
        let remote =
            plan_fetches(&topo, &m, &[Some(1)], &[Some(0)], &bytes, 1);
        assert!(
            remote.fetch_s > local.fetch_s * 5.0,
            "cross-rack fetch must pay the core link: {} vs {}",
            remote.fetch_s,
            local.fetch_s
        );
        assert_eq!(remote.bytes_off_rack, 100_000_000);
        assert_eq!(local.bytes_node_local, 100_000_000);
    }

    #[test]
    fn parallelism_shrinks_the_fetch_wall() {
        let topo = RackTopology::single(2);
        let m = model();
        let seg: Vec<Vec<u64>> = (0..8).map(|_| vec![10_000_000u64]).collect();
        let maps: Vec<Option<usize>> = (0..8).map(|_| Some(1)).collect();
        let serial = plan_fetches(&topo, &m, &maps, &[Some(0)], &seg, 1);
        let wide = plan_fetches(&topo, &m, &maps, &[Some(0)], &seg, 8);
        assert!(wide.fetch_s < serial.fetch_s / 4.0);
        // Total bytes identical either way.
        assert_eq!(wide.total_bytes(), serial.total_bytes());
    }

    #[test]
    fn empty_segments_are_not_fetched() {
        let topo = RackTopology::single(2);
        let m = model();
        let seg = vec![vec![0u64, 500], vec![0, 0]];
        let plan = plan_fetches(
            &topo,
            &m,
            &[Some(0), Some(1)],
            &[Some(0), Some(1)],
            &seg,
            4,
        );
        assert_eq!(plan.fetches, 1);
        assert_eq!(plan.total_bytes(), 500);
    }

    #[test]
    fn latency_charged_per_wave() {
        let topo = RackTopology::single(1);
        let m = NetworkModel {
            shuffle_latency_s: 1.0,
            disk_bw: 1e18,
            ..NetworkModel::default()
        };
        let seg: Vec<Vec<u64>> = (0..10).map(|_| vec![1u64]).collect();
        let maps: Vec<Option<usize>> = (0..10).map(|_| Some(0)).collect();
        // 10 fetches, 4 streams -> 3 waves.
        let plan = plan_fetches(&topo, &m, &maps, &[Some(0)], &seg, 4);
        assert!((plan.fetch_s - 3.0).abs() < 1e-9, "{}", plan.fetch_s);
    }
}
