//! The shuffle subsystem: Hadoop's sort/spill/merge pipeline in miniature.
//!
//! Map side ([`buffer`]): every emitted record lands in a bounded sort
//! buffer (`io.sort.mb` analog). When the buffer fills, it is sorted by
//! (partition, key) with an unstable sort, the combiner runs once per key
//! group, and the run is written out as one **spill** — a sorted
//! [`Segment`] per reduce partition. At task end the spills are k-way
//! merged (`io.sort.factor` analog) into exactly one segment per
//! partition: the task's map output file.
//!
//! Reduce side ([`merge`], [`fetch`]): each reduce task *fetches* its
//! partition's segment from every map output. Fetches are charged through
//! the scheduler's locality tiers — a segment on the reducer's own node
//! streams from local disk, one in the rack pays the top-of-rack switch,
//! and a cross-rack fetch pays the oversubscribed core link
//! ([`fetch::plan_fetches`]). The fetched segments are merged down to at
//! most `merge_factor` runs (extra runs cost a merge pass and re-spill,
//! like Hadoop's on-disk merges) and then streamed — never materialized —
//! through [`merge::GroupedMerge`] into [`Reducer::reduce`] one key group
//! at a time.
//!
//! Counters: `SPILLS`, `SPILLED_RECORDS`, `MERGE_PASSES` and the
//! per-tier `SHUFFLE_FETCH_BYTES_*` family surface the whole lifecycle
//! (see `mapreduce::counters::names` and `metrics::report`).
//!
//! [`Reducer::reduce`]: crate::mapreduce::Reducer::reduce

pub mod buffer;
pub mod fetch;
pub mod merge;

pub use buffer::{MapShuffleOutput, SpillCollector};
pub use fetch::{plan_fetches, FetchPlan, ReducerFetch};
pub use merge::{merge_records, merge_to_factor, GroupedMerge, Segment, ValueStream};

/// Shuffle tuning knobs (Hadoop's `io.sort.*` / `mapred.reduce.parallel.copies`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShuffleConfig {
    /// Map-side sort buffer size in KiB (`io.sort.mb` analog): the buffer
    /// spills to a sorted segment run whenever the buffered key+value
    /// bytes reach this bound.
    pub sort_buffer_kb: usize,
    /// Maximum segments merged in one pass (`io.sort.factor` analog), on
    /// both the map side (spill merge) and the reduce side (fetch merge).
    pub merge_factor: usize,
    /// Concurrent fetch streams per reduce task
    /// (`mapred.reduce.parallel.copies` analog).
    pub fetch_parallelism: usize,
}

impl Default for ShuffleConfig {
    fn default() -> Self {
        Self {
            // Scaled-down io.sort.mb=100MB for our miniature jobs.
            sort_buffer_kb: 512,
            // Hadoop's io.sort.factor default.
            merge_factor: 10,
            // Hadoop's parallel-copies default.
            fetch_parallelism: 5,
        }
    }
}

impl ShuffleConfig {
    /// Spill threshold in bytes.
    pub fn sort_buffer_bytes(&self) -> usize {
        self.sort_buffer_kb.saturating_mul(1024).max(1)
    }

    /// Merge factor clamped to a sane floor.
    pub fn factor(&self) -> usize {
        self.merge_factor.max(2)
    }

    /// Fetch parallelism clamped to a sane floor.
    pub fn parallelism(&self) -> usize {
        self.fetch_parallelism.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ShuffleConfig::default();
        assert_eq!(c.sort_buffer_bytes(), 512 * 1024);
        assert_eq!(c.factor(), 10);
        assert_eq!(c.parallelism(), 5);
    }

    #[test]
    fn floors_clamp_degenerate_knobs() {
        let c = ShuffleConfig {
            sort_buffer_kb: 0,
            merge_factor: 0,
            fetch_parallelism: 0,
        };
        assert_eq!(c.sort_buffer_bytes(), 1);
        assert_eq!(c.factor(), 2);
        assert_eq!(c.parallelism(), 1);
    }
}
