//! Map-side sort/spill buffer (Hadoop's MapOutputBuffer in miniature).
//!
//! Emitted records accumulate in a bounded buffer; when the buffered bytes
//! reach `sort_buffer_kb`, the run is sorted by (partition, key), the
//! combiner runs once per key group, and one sorted [`Segment`] per
//! partition is spilled. At task end the spills are merged down to one
//! segment per partition under the `merge_factor` bound.

use std::sync::Arc;

use crate::error::Result;

use super::super::types::{Bytes, Partitioner, Reducer, TaskContext, KV};
use super::merge::{merge_records, merge_to_factor, GroupedMerge, Segment};
use super::ShuffleConfig;

/// The finished map output: one sorted segment per reduce partition plus
/// the spill/merge tallies that feed the job counters.
#[derive(Debug, Default)]
pub struct MapShuffleOutput {
    /// One sorted segment per reduce partition (empty segments included,
    /// so `segments[p]` is always this map's output for partition `p`).
    pub segments: Vec<Segment>,
    /// Records collected from the mapper (pre-combine) — the task's
    /// map-output record count.
    pub input_records: u64,
    /// Spills performed (>= 1 whenever the task emitted anything).
    pub spills: u64,
    /// Records written across all spills and intermediate merge passes.
    pub spilled_records: u64,
    /// Intermediate + final merge passes that combined multiple runs.
    pub merge_passes: u64,
    /// Records surviving the combiner (0 when no combiner installed).
    pub combine_output_records: u64,
}

impl MapShuffleOutput {
    /// Total intermediate bytes this map contributes to the shuffle.
    pub fn bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.bytes()).sum()
    }
}

/// The spill collector: owns the sort buffer and the spilled runs of one
/// map task attempt.
pub struct SpillCollector {
    nparts: usize,
    partitioner: Arc<dyn Partitioner>,
    combiner: Option<Arc<dyn Reducer>>,
    cfg: ShuffleConfig,
    /// (partition, record) pairs awaiting the next spill.
    buffer: Vec<(usize, KV)>,
    buffered_bytes: usize,
    /// spills[i][p] = partition p's sorted run from spill i.
    spills: Vec<Vec<Segment>>,
    /// Records collected (pre-combine) — the map-output record count.
    pub input_records: u64,
    spilled_records: u64,
    combine_output_records: u64,
}

impl SpillCollector {
    /// Collector for `nparts` reduce partitions.
    pub fn new(
        nparts: usize,
        partitioner: Arc<dyn Partitioner>,
        combiner: Option<Arc<dyn Reducer>>,
        cfg: ShuffleConfig,
    ) -> Self {
        Self {
            nparts: nparts.max(1),
            partitioner,
            combiner,
            cfg,
            buffer: Vec::new(),
            buffered_bytes: 0,
            spills: Vec::new(),
            input_records: 0,
            spilled_records: 0,
            combine_output_records: 0,
        }
    }

    /// Add one emitted record; spills when the buffer bound is reached.
    pub fn collect(&mut self, key: Bytes, value: Bytes) -> Result<()> {
        let p = self.partitioner.partition(&key, self.nparts);
        self.buffered_bytes += key.len() + value.len();
        self.buffer.push((p, (key, value)));
        self.input_records += 1;
        if self.buffered_bytes >= self.cfg.sort_buffer_bytes() {
            self.spill()?;
        }
        Ok(())
    }

    /// Sort the buffered run by (partition, key) and write one segment per
    /// partition, running the combiner per key group.
    fn spill(&mut self) -> Result<()> {
        if self.buffer.is_empty() {
            return Ok(());
        }
        let mut buf = std::mem::take(&mut self.buffer);
        self.buffered_bytes = 0;
        buf.sort_unstable_by(|a, b| a.0.cmp(&b.0).then_with(|| (a.1).0.cmp(&(b.1).0)));
        // Pre-size each partition's run from its record count instead of
        // growing from empty.
        let mut counts = vec![0usize; self.nparts];
        for (p, _) in &buf {
            counts[*p] += 1;
        }
        let mut runs: Vec<Vec<KV>> =
            counts.iter().map(|&c| Vec::with_capacity(c)).collect();
        for (p, kv) in buf {
            runs[p].push(kv);
        }
        let mut segs = Vec::with_capacity(self.nparts);
        for run in runs {
            let mut seg = Segment::from_sorted(run);
            match &self.combiner {
                Some(c) if !seg.is_empty() => {
                    seg = combine_segment(seg, c.as_ref())?;
                    self.combine_output_records += seg.len() as u64;
                }
                _ => {}
            }
            self.spilled_records += seg.len() as u64;
            segs.push(seg);
        }
        self.spills.push(segs);
        Ok(())
    }

    /// Final spill + per-partition merge down to one segment each.
    pub fn finish(mut self) -> Result<MapShuffleOutput> {
        self.spill()?;
        let mut out = MapShuffleOutput {
            segments: Vec::with_capacity(self.nparts),
            input_records: self.input_records,
            spills: self.spills.len() as u64,
            spilled_records: self.spilled_records,
            merge_passes: 0,
            combine_output_records: self.combine_output_records,
        };
        let mut spills = self.spills;
        for p in 0..self.nparts {
            let runs: Vec<Segment> = spills
                .iter_mut()
                .map(|segs| std::mem::take(&mut segs[p]))
                .filter(|s| !s.is_empty())
                .collect();
            let (mut remaining, passes, rewritten) =
                merge_to_factor(runs, self.cfg.factor());
            out.merge_passes += passes;
            out.spilled_records += rewritten;
            let seg = match remaining.len() {
                0 => Segment::default(),
                1 => remaining.pop().unwrap(),
                // Final merge streams to the map output file — a pass, but
                // not a re-spill.
                _ => {
                    out.merge_passes += 1;
                    merge_records(remaining)
                }
            };
            out.segments.push(seg);
        }
        Ok(out)
    }
}

/// Run the combiner over one sorted run, yielding the combined (sorted)
/// run. Group values stream from the segment; combiner counters are
/// dropped (matching Hadoop, which folds them into the task's own).
pub fn combine_segment(seg: Segment, combiner: &dyn Reducer) -> Result<Segment> {
    let segs = [seg];
    let mut gm = GroupedMerge::new(&segs);
    let mut ctx = TaskContext::default();
    while let Some(key) = gm.next_key() {
        let mut vs = gm.values();
        combiner.reduce(&key, &mut vs, &mut ctx)?;
    }
    let (out, _counters) = ctx.into_parts();
    // Combiners emit per group in key order, but nothing forces the keys
    // they emit to match the group key — re-sort to keep the invariant.
    Ok(Segment::from_unsorted(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::types::{FnReducer, HashPartitioner, Values};
    use crate::util::bytes::{decode_u64, encode_u64};

    fn sum_combiner() -> Arc<dyn Reducer> {
        Arc::new(FnReducer(
            |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
                let mut total = 0u64;
                while let Some(v) = vs.next_value() {
                    total += decode_u64(v);
                }
                ctx.emit(k.to_vec(), encode_u64(total).to_vec());
                Ok(())
            },
        ))
    }

    fn collector(
        nparts: usize,
        buffer_kb: usize,
        combiner: Option<Arc<dyn Reducer>>,
    ) -> SpillCollector {
        SpillCollector::new(
            nparts,
            Arc::new(HashPartitioner),
            combiner,
            ShuffleConfig {
                sort_buffer_kb: buffer_kb,
                ..ShuffleConfig::default()
            },
        )
    }

    fn feed(c: &mut SpillCollector, n: u64) {
        for i in 0..n {
            c.collect(encode_u64(i % 16).to_vec(), encode_u64(1).to_vec())
                .unwrap();
        }
    }

    #[test]
    fn tiny_buffer_spills_every_record() {
        let mut c = collector(3, 0, None); // floor: 1-byte threshold
        feed(&mut c, 100);
        let out = c.finish().unwrap();
        assert_eq!(out.segments.len(), 3);
        assert!(out.spills >= 99, "every record should trigger a spill");
        assert!(
            out.spilled_records >= 100,
            "spilled {} < emitted 100",
            out.spilled_records
        );
        let total: usize = out.segments.iter().map(|s| s.len()).sum();
        assert_eq!(total, 100, "no records lost");
    }

    #[test]
    fn huge_buffer_spills_once() {
        let mut c = collector(3, 1 << 20, None);
        feed(&mut c, 100);
        let out = c.finish().unwrap();
        assert_eq!(out.spills, 1);
        assert_eq!(out.spilled_records, 100);
        assert_eq!(out.merge_passes, 0, "single spill needs no merge");
    }

    #[test]
    fn segments_are_sorted_and_partitioned() {
        let mut c = collector(4, 0, None);
        feed(&mut c, 200);
        let out = c.finish().unwrap();
        let p = HashPartitioner;
        for (part, seg) in out.segments.iter().enumerate() {
            for i in 0..seg.len() {
                assert_eq!(p.partition(seg.key(i), 4), part, "record misrouted");
                if i > 0 {
                    assert!(seg.key(i - 1) <= seg.key(i), "segment unsorted");
                }
            }
        }
    }

    #[test]
    fn combiner_shrinks_spills_but_conserves_sums() {
        let mut plain = collector(2, 1 << 20, None);
        feed(&mut plain, 160);
        let plain_out = plain.finish().unwrap();

        let mut combined = collector(2, 1 << 20, Some(sum_combiner()));
        feed(&mut combined, 160);
        let out = combined.finish().unwrap();
        assert!(out.bytes() < plain_out.bytes(), "combiner should shrink output");
        assert_eq!(out.combine_output_records, 16, "one record per key");
        let total: u64 = out
            .segments
            .iter()
            .flat_map(|s| (0..s.len()).map(|i| decode_u64(s.value(i))))
            .sum();
        assert_eq!(total, 160, "combined sums must conserve the total");
    }

    #[test]
    fn empty_task_produces_empty_segments() {
        let c = collector(2, 64, None);
        let out = c.finish().unwrap();
        assert_eq!(out.segments.len(), 2);
        assert!(out.segments.iter().all(|s| s.is_empty()));
        assert_eq!(out.spills, 0);
        assert_eq!(out.spilled_records, 0);
    }

    #[test]
    fn many_tiny_spills_merge_down_with_passes() {
        let mut c = collector(1, 0, None);
        feed(&mut c, 64);
        let out = c.finish().unwrap();
        assert_eq!(out.segments.len(), 1);
        assert_eq!(out.segments[0].len(), 64);
        assert!(out.merge_passes >= 1, "64 spills must merge in passes");
        assert!(
            out.spilled_records > 64,
            "intermediate passes rewrite records: {}",
            out.spilled_records
        );
    }
}
