//! MapReduce core types: records, Mapper/Reducer/Partitioner traits.

use crate::error::Result;

use super::counters::Counters;

/// Raw bytes (keys and values are untyped, codecs live in `util::bytes`).
pub type Bytes = Vec<u8>;
/// One record.
pub type KV = (Bytes, Bytes);
/// One input split: the records a single map task consumes.
pub type InputSplit = Vec<KV>;

/// Per-task context: collects emitted records and counter increments.
#[derive(Debug, Default)]
pub struct TaskContext {
    emits: Vec<KV>,
    counters: Counters,
}

impl TaskContext {
    /// Emit an intermediate/output record.
    pub fn emit(&mut self, key: Bytes, value: Bytes) {
        self.emits.push((key, value));
    }

    /// Bump a user counter.
    pub fn incr(&mut self, name: &str, delta: u64) {
        self.counters.incr(name, delta);
    }

    /// Drain the records emitted since the last drain (the engine feeds
    /// these into the spill buffer between map calls).
    pub fn take_emits(&mut self) -> Vec<KV> {
        std::mem::take(&mut self.emits)
    }

    /// Consume the context.
    pub fn into_parts(self) -> (Vec<KV>, Counters) {
        (self.emits, self.counters)
    }

    /// Merge an already-aggregated counter set into this context (the
    /// dataflow layer's fused mappers run inner stages against scratch
    /// contexts and fold their counters back here).
    pub fn merge_counters(&mut self, other: &Counters) {
        self.counters.merge(other);
    }

    /// Emitted records so far (tests).
    pub fn emitted(&self) -> &[KV] {
        &self.emits
    }
}

/// Map function (paper Fig. 1/3: the `map(<key,value>, <key',value'>)`).
pub trait Mapper: Send + Sync {
    /// Process one record.
    fn map(&self, key: &[u8], value: &[u8], ctx: &mut TaskContext) -> Result<()>;
}

/// Streaming view of one key group's values.
///
/// The reduce-side merge ([`crate::mapreduce::shuffle::GroupedMerge`])
/// feeds this lazily from the fetched segments: values are pulled one at
/// a time and a reduce partition is never materialized. Each returned
/// slice is borrowed until the next pull — decode or copy what you keep.
pub trait Values {
    /// The next value of the group, or `None` when the group is done.
    fn next_value(&mut self) -> Option<&[u8]>;
}

/// [`Values`] over a value slice (tests and adapters).
pub struct SliceValues<'a> {
    values: &'a [Bytes],
    next: usize,
}

impl<'a> SliceValues<'a> {
    /// Stream the given values in order.
    pub fn new(values: &'a [Bytes]) -> Self {
        Self { values, next: 0 }
    }
}

impl Values for SliceValues<'_> {
    fn next_value(&mut self) -> Option<&[u8]> {
        let v = self.values.get(self.next)?;
        self.next += 1;
        Some(v)
    }
}

/// Reduce function over one key group (also used as a combiner).
///
/// Values arrive as a stream, not a materialized vector: Hadoop's
/// `reduce(key, Iterator<values>)` contract, which is what lets a reducer
/// process a group far larger than memory.
pub trait Reducer: Send + Sync {
    /// Process one key and the stream of its values.
    fn reduce(
        &self,
        key: &[u8],
        values: &mut dyn Values,
        ctx: &mut TaskContext,
    ) -> Result<()>;
}

/// Route a key to one of `n` reduce partitions.
pub trait Partitioner: Send + Sync {
    /// Partition index in [0, n).
    fn partition(&self, key: &[u8], n: usize) -> usize;
}

/// Default partitioner: FNV-1a hash of the key, mod n (Hadoop's HashPartitioner).
#[derive(Debug, Default, Clone)]
pub struct HashPartitioner;

impl Partitioner for HashPartitioner {
    fn partition(&self, key: &[u8], n: usize) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in key {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        (h % n as u64) as usize
    }
}

/// Range partitioner over big-endian u64 row keys: preserves global order
/// across reducer outputs (used when reduce output is re-assembled into a
/// row-ordered matrix).
#[derive(Debug, Clone)]
pub struct RangePartitioner {
    /// Exclusive upper bound of the key space.
    pub max_key: u64,
}

impl Partitioner for RangePartitioner {
    fn partition(&self, key: &[u8], n: usize) -> usize {
        let k = crate::util::bytes::decode_u64(key);
        let bucket = (k as u128 * n as u128 / self.max_key.max(1) as u128) as usize;
        bucket.min(n - 1)
    }
}

/// Closure-backed mapper (ergonomics for small jobs and tests).
pub struct FnMapper<F>(pub F);

impl<F> Mapper for FnMapper<F>
where
    F: Fn(&[u8], &[u8], &mut TaskContext) -> Result<()> + Send + Sync,
{
    fn map(&self, key: &[u8], value: &[u8], ctx: &mut TaskContext) -> Result<()> {
        (self.0)(key, value, ctx)
    }
}

/// Closure-backed reducer.
pub struct FnReducer<F>(pub F);

impl<F> Reducer for FnReducer<F>
where
    F: Fn(&[u8], &mut dyn Values, &mut TaskContext) -> Result<()> + Send + Sync,
{
    fn reduce(
        &self,
        key: &[u8],
        values: &mut dyn Values,
        ctx: &mut TaskContext,
    ) -> Result<()> {
        (self.0)(key, values, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_partitioner_in_range_and_deterministic() {
        let p = HashPartitioner;
        for n in [1usize, 2, 7, 16] {
            for key in [b"".as_slice(), b"a", b"abc", &[0u8, 1, 2, 3]] {
                let part = p.partition(key, n);
                assert!(part < n);
                assert_eq!(part, p.partition(key, n));
            }
        }
    }

    #[test]
    fn hash_partitioner_spreads_keys() {
        let p = HashPartitioner;
        let n = 8;
        let mut counts = vec![0usize; n];
        for i in 0..10_000u64 {
            counts[p.partition(&i.to_be_bytes(), n)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 10_000 / n / 2, "partition {i} starved: {counts:?}");
        }
    }

    #[test]
    fn range_partitioner_order_preserving() {
        let p = RangePartitioner { max_key: 1000 };
        let n = 4;
        let mut last = 0;
        for k in 0..1000u64 {
            let part = p.partition(&k.to_be_bytes(), n);
            assert!(part >= last, "range partitioner went backwards");
            assert!(part < n);
            last = part;
        }
        // All partitions used.
        let used: std::collections::HashSet<usize> =
            (0..1000u64).map(|k| p.partition(&k.to_be_bytes(), n)).collect();
        assert_eq!(used.len(), n);
    }

    #[test]
    fn task_context_collects() {
        let mut ctx = TaskContext::default();
        ctx.emit(vec![1], vec![2]);
        ctx.incr("c", 3);
        let (emits, counters) = ctx.into_parts();
        assert_eq!(emits, vec![(vec![1], vec![2])]);
        assert_eq!(counters.get("c"), 3);
    }

    #[test]
    fn task_context_take_emits_drains() {
        let mut ctx = TaskContext::default();
        ctx.emit(vec![1], vec![2]);
        assert_eq!(ctx.take_emits(), vec![(vec![1], vec![2])]);
        assert!(ctx.take_emits().is_empty());
        ctx.emit(vec![3], vec![4]);
        assert_eq!(ctx.take_emits().len(), 1);
    }

    #[test]
    fn slice_values_streams_in_order() {
        let vals: Vec<Bytes> = vec![vec![1], vec![2], vec![3]];
        let mut vs = SliceValues::new(&vals);
        let mut seen = Vec::new();
        while let Some(v) = vs.next_value() {
            seen.push(v[0]);
        }
        assert_eq!(seen, vec![1, 2, 3]);
        assert!(vs.next_value().is_none());
    }

    #[test]
    fn fn_reducer_streams_values() {
        let r = FnReducer(
            |k: &[u8], vs: &mut dyn Values, ctx: &mut TaskContext| {
                let mut n = 0u64;
                while let Some(_v) = vs.next_value() {
                    n += 1;
                }
                ctx.emit(k.to_vec(), vec![n as u8]);
                Ok(())
            },
        );
        let vals: Vec<Bytes> = vec![vec![0]; 5];
        let mut vs = SliceValues::new(&vals);
        let mut ctx = TaskContext::default();
        r.reduce(b"k", &mut vs, &mut ctx).unwrap();
        assert_eq!(ctx.emitted(), &[(b"k".to_vec(), vec![5])]);
    }
}
