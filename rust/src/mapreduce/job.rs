//! Job configuration (Hadoop's JobConf) with a builder API.
//!
//! Failure handling is no longer a per-job concern: the legacy
//! `Job::fault` injector and `Job::max_attempts` knob were replaced by the
//! cluster-wide failure domain (`[faults]` config →
//! [`crate::cluster::FaultConfig`]), where attempt failures, node deaths
//! and blacklisting are decided for every job alike. See DESIGN.md §2.9.

use std::sync::Arc;

use super::shuffle::ShuffleConfig;
use super::types::{HashPartitioner, InputSplit, Mapper, Partitioner, Reducer};

/// A fully-specified MapReduce job.
pub struct Job {
    /// Human-readable job name (logs, metrics).
    pub name: String,
    /// One entry per map task.
    pub input: Vec<InputSplit>,
    /// Preferred hosts per map split (nodes holding the split's DFS blocks
    /// or table region), parallel to `input`. Empty, or shorter than
    /// `input`, means the missing splits carry no locality preference.
    pub split_hosts: Vec<Vec<usize>>,
    /// The map function.
    pub mapper: Arc<dyn Mapper>,
    /// The reduce function; `None` = map-only job (paper Alg. 4.2 is one).
    pub reducer: Option<Arc<dyn Reducer>>,
    /// Optional map-side combiner (same contract as the reducer).
    pub combiner: Option<Arc<dyn Reducer>>,
    /// Number of reduce partitions.
    pub num_reducers: usize,
    /// Key router.
    pub partitioner: Arc<dyn Partitioner>,
    /// Per-job shuffle knobs (`None` = the cluster's configuration), like
    /// Hadoop's per-job `io.sort.*` overrides in the JobConf.
    pub shuffle: Option<ShuffleConfig>,
}

/// Builder for [`Job`].
pub struct JobBuilder {
    job: Job,
}

impl JobBuilder {
    /// Start building a job with the mandatory pieces.
    pub fn new(name: &str, input: Vec<InputSplit>, mapper: Arc<dyn Mapper>) -> Self {
        Self {
            job: Job {
                name: name.to_string(),
                input,
                split_hosts: Vec::new(),
                mapper,
                reducer: None,
                combiner: None,
                num_reducers: 1,
                partitioner: Arc::new(HashPartitioner),
                shuffle: None,
            },
        }
    }

    /// Set the reducer and partition count.
    pub fn reducer(mut self, r: Arc<dyn Reducer>, num_reducers: usize) -> Self {
        self.job.reducer = Some(r);
        self.job.num_reducers = num_reducers.max(1);
        self
    }

    /// Set a map-side combiner.
    pub fn combiner(mut self, c: Arc<dyn Reducer>) -> Self {
        self.job.combiner = Some(c);
        self
    }

    /// Declare the preferred hosts of every map split (the scheduler's
    /// locality input; see [`Job::split_hosts`]).
    pub fn split_hosts(mut self, hosts: Vec<Vec<usize>>) -> Self {
        self.job.split_hosts = hosts;
        self
    }

    /// Replace the partitioner.
    pub fn partitioner(mut self, p: Arc<dyn Partitioner>) -> Self {
        self.job.partitioner = p;
        self
    }

    /// Override the cluster's shuffle knobs for this job.
    pub fn shuffle_config(mut self, cfg: ShuffleConfig) -> Self {
        self.job.shuffle = Some(cfg);
        self
    }

    /// Finish.
    pub fn build(self) -> Job {
        self.job
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapreduce::types::FnMapper;

    #[test]
    fn builder_defaults() {
        let j = JobBuilder::new(
            "t",
            vec![],
            Arc::new(FnMapper(|_: &[u8], _: &[u8], _: &mut _| Ok(()))),
        )
        .build();
        assert_eq!(j.name, "t");
        assert!(j.reducer.is_none());
        assert!(j.combiner.is_none());
        assert_eq!(j.num_reducers, 1);
        assert!(j.split_hosts.is_empty());
        assert!(j.shuffle.is_none(), "cluster shuffle config by default");
    }

    #[test]
    fn builder_sets_shuffle_override() {
        let j = JobBuilder::new(
            "t",
            vec![],
            Arc::new(FnMapper(|_: &[u8], _: &[u8], _: &mut _| Ok(()))),
        )
        .shuffle_config(ShuffleConfig {
            sort_buffer_kb: 4,
            merge_factor: 3,
            fetch_parallelism: 2,
        })
        .build();
        assert_eq!(j.shuffle.unwrap().merge_factor, 3);
    }

    #[test]
    fn builder_sets_split_hosts() {
        let j = JobBuilder::new(
            "t",
            vec![vec![], vec![]],
            Arc::new(FnMapper(|_: &[u8], _: &[u8], _: &mut _| Ok(()))),
        )
        .split_hosts(vec![vec![0, 2], vec![1]])
        .build();
        assert_eq!(j.split_hosts, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn builder_clamps_reducers() {
        let j = JobBuilder::new(
            "t",
            vec![],
            Arc::new(FnMapper(|_: &[u8], _: &[u8], _: &mut _| Ok(()))),
        )
        .reducer(
            Arc::new(crate::mapreduce::types::FnReducer(
                |_: &[u8], _: &mut dyn crate::mapreduce::types::Values, _: &mut _| Ok(()),
            )),
            0,
        )
        .build();
        assert_eq!(j.num_reducers, 1, "num_reducers clamps to >= 1");
    }
}
