//! MapReduce engine (paper §2.2): jobs, tasks, shuffle, counters, retries.
//!
//! The programming model is Hadoop's: a [`Mapper`] over input splits, an
//! optional map-side combiner, a [`Partitioner`] routing keys to reduce
//! partitions, the [`shuffle`] subsystem (sort/spill/merge on the map
//! side, locality-charged fetches and a streaming grouped merge on the
//! reduce side), and a [`Reducer`] per partition consuming each key
//! group's values as a stream. Tasks execute on the simulated
//! [`crate::cluster::Cluster`]; failed tasks are re-executed on fresh
//! rounds and the cluster's failure domain ([`crate::cluster::faults`])
//! injects attempt failures, node deaths and blacklisting into the
//! virtual-time model that reproduces the paper's scaling numbers.

pub mod counters;
pub mod engine;
pub mod job;
pub mod shuffle;
pub mod types;

pub use counters::{names, Counters};
pub use engine::{run, JobResult, JobStats};
pub use job::{Job, JobBuilder};
pub use shuffle::ShuffleConfig;
pub use types::{
    Bytes, FnMapper, FnReducer, HashPartitioner, InputSplit, Mapper, Partitioner,
    RangePartitioner, Reducer, SliceValues, TaskContext, Values, KV,
};
