//! MapReduce engine (paper §2.2): jobs, tasks, shuffle, counters, retries.
//!
//! The programming model is Hadoop's: a [`Mapper`] over input splits, an
//! optional map-side combiner, a [`Partitioner`] routing keys to reduce
//! partitions, a sort-merge shuffle, and a [`Reducer`] per partition. Tasks
//! execute on the simulated [`crate::cluster::Cluster`] with per-task retry
//! and fault injection; every task's measured cost feeds the virtual-time
//! model that reproduces the paper's scaling numbers.

pub mod counters;
pub mod engine;
pub mod job;
pub mod types;

pub use counters::{names, Counters};
pub use engine::{run, JobResult, JobStats};
pub use job::{FaultInjector, Job, JobBuilder, Phase};
pub use types::{
    Bytes, FnMapper, FnReducer, HashPartitioner, InputSplit, Mapper, Partitioner,
    RangePartitioner, Reducer, TaskContext, KV,
};
