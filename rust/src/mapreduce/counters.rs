//! Job counters (Hadoop's Counters in miniature).

use std::collections::BTreeMap;

/// Named monotonic counters, mergeable across tasks.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    values: BTreeMap<String, u64>,
}

/// Well-known counter names used by the engine.
pub mod names {
    /// Records fed to mappers.
    pub const MAP_INPUT_RECORDS: &str = "MAP_INPUT_RECORDS";
    /// Records emitted by mappers.
    pub const MAP_OUTPUT_RECORDS: &str = "MAP_OUTPUT_RECORDS";
    /// Records after the combiner (== map output when no combiner).
    pub const COMBINE_OUTPUT_RECORDS: &str = "COMBINE_OUTPUT_RECORDS";
    /// Bytes crossing the shuffle.
    pub const SHUFFLE_BYTES: &str = "SHUFFLE_BYTES";
    /// Distinct keys seen by reducers.
    pub const REDUCE_INPUT_GROUPS: &str = "REDUCE_INPUT_GROUPS";
    /// Records emitted by reducers.
    pub const REDUCE_OUTPUT_RECORDS: &str = "REDUCE_OUTPUT_RECORDS";
    /// Map task attempts that failed (fault injection / mapper errors).
    pub const FAILED_MAP_ATTEMPTS: &str = "FAILED_MAP_ATTEMPTS";
    /// Extra bytes a task read outside its split (table scans, DFS side
    /// files); charged to the task's virtual input cost by the engine.
    pub const EXTRA_INPUT_BYTES: &str = "EXTRA_INPUT_BYTES";
    /// Extra bytes a task wrote outside its emits (table puts, DFS writes).
    pub const EXTRA_OUTPUT_BYTES: &str = "EXTRA_OUTPUT_BYTES";
    /// Modeled task compute in MICROseconds on the *reference* machine
    /// (the paper's testbed). When a task reports this, it REPLACES the
    /// measured wall time in the virtual-clock cost — measured times on a
    /// shared host are noisy, and noise × compute_scale would swamp the
    /// deterministic makespan model. See coordinator::costmodel.
    pub const COMPUTE_US: &str = "COMPUTE_US";
    /// Reduce task attempts that failed.
    pub const FAILED_REDUCE_ATTEMPTS: &str = "FAILED_REDUCE_ATTEMPTS";
    /// Map tasks whose winning attempt ran on a node holding its split
    /// (only tasks that declared split locations are counted).
    pub const DATA_LOCAL_MAPS: &str = "DATA_LOCAL_MAPS";
    /// Map tasks whose winning attempt ran in the split's rack.
    pub const RACK_LOCAL_MAPS: &str = "RACK_LOCAL_MAPS";
    /// Map tasks whose winning attempt read across racks.
    pub const OFF_RACK_MAPS: &str = "OFF_RACK_MAPS";
    /// Speculative duplicate attempts the JobTracker launched.
    pub const SPECULATIVE_ATTEMPTS: &str = "SPECULATIVE_ATTEMPTS";
    /// Speculative duplicates that beat the original attempt.
    pub const SPECULATIVE_WINS: &str = "SPECULATIVE_WINS";
    /// TaskTracker heartbeats processed while the job ran (virtual).
    pub const HEARTBEATS: &str = "HEARTBEATS";
    /// Virtual MICROseconds map tasks spent reading input at their placed
    /// locality tier — the number the locality ablation compares.
    pub const MAP_READ_US: &str = "MAP_READ_US";
    /// Map-side sort-buffer spills (>= 1 per map task that emitted).
    pub const SPILLS: &str = "SPILLS";
    /// Records written to spill runs plus records rewritten by
    /// intermediate merge passes (Hadoop's SPILLED_RECORDS, map and
    /// reduce side combined).
    pub const SPILLED_RECORDS: &str = "SPILLED_RECORDS";
    /// Merge passes that combined multiple sorted runs (map-side spill
    /// merges + reduce-side fetch merges).
    pub const MERGE_PASSES: &str = "MERGE_PASSES";
    /// Shuffle bytes fetched from the reducer's own node.
    pub const SHUFFLE_FETCH_BYTES_LOCAL: &str = "SHUFFLE_FETCH_BYTES_LOCAL";
    /// Shuffle bytes fetched from another node in the reducer's rack.
    pub const SHUFFLE_FETCH_BYTES_RACK: &str = "SHUFFLE_FETCH_BYTES_RACK";
    /// Shuffle bytes fetched across racks (the oversubscribed core link).
    pub const SHUFFLE_FETCH_BYTES_REMOTE: &str = "SHUFFLE_FETCH_BYTES_REMOTE";
    /// Virtual MICROseconds reducers spent fetching segments (serial sum
    /// across reducers).
    pub const SHUFFLE_FETCH_US: &str = "SHUFFLE_FETCH_US";
    /// Completed map tasks re-executed on a live node because the slave
    /// holding their output died (Hadoop's signature lost-output case).
    pub const MAP_RERUNS: &str = "MAP_RERUNS";
    /// Reduce-side segment fetches that targeted a dead slave's map
    /// output — each one triggers the map's re-execution.
    pub const FETCH_FAILURES: &str = "FETCH_FAILURES";
    /// Slaves blacklisted during the job (too many failed attempts; no
    /// further attempts are assigned to them).
    pub const BLACKLISTED_SLAVES: &str = "BLACKLISTED_SLAVES";
    /// Scheduled node deaths that fired while the job's phases ran.
    pub const NODE_DEATHS: &str = "NODE_DEATHS";
    /// Candidate pairs the epsilon-mode similarity mappers priced in full
    /// (every tile cell — the all-pairs baseline the t-NN path undercuts).
    pub const SIM_PAIRS_EVALUATED: &str = "SIM_PAIRS_EVALUATED";
    /// Candidate pairs the t-NN spatial index priced in full (completed
    /// distance evaluations).
    pub const KNN_PAIRS_EVALUATED: &str = "KNN_PAIRS_EVALUATED";
    /// Candidate pairs the t-NN index dismissed without a full distance —
    /// bounding-box subtree pruning plus partial-distance early exits.
    pub const KNN_PRUNED_PAIRS: &str = "KNN_PRUNED_PAIRS";
    /// Neighbors displaced from full top-t heaps during t-NN queries.
    pub const KNN_HEAP_EVICTIONS: &str = "KNN_HEAP_EVICTIONS";
    /// Jobs the eigen phase launched (Laplacian build + every operator
    /// application) — the quantity the ChebDav backend exists to shrink.
    pub const EIGEN_JOBS: &str = "EIGEN_JOBS";
    /// Mat-vecs priced across the eigen phase's operator jobs: 1 per
    /// lanczos mat-vec job, m per ChebDav block job (Σ block widths).
    pub const MATVECS_BATCHED: &str = "MATVECS_BATCHED";
    /// Chebyshev filter degree the ChebDav backend ran with (0 under
    /// lanczos — the counter doubles as the backend marker in reports).
    pub const CHEB_FILTER_DEGREE: &str = "CHEB_FILTER_DEGREE";
    /// Points assigned by the serving layer's Nyström extension mappers
    /// (`psch assign`), summed across batches.
    pub const ASSIGN_POINTS: &str = "ASSIGN_POINTS";
    /// Assign pipelines launched by the serving layer (one per point batch).
    pub const ASSIGN_BATCHES: &str = "ASSIGN_BATCHES";
    /// Centroids moved by mini-batch refresh (`serving.refresh =
    /// minibatch`): one count per (batch, cluster) counted update applied.
    pub const REFRESH_UPDATES: &str = "REFRESH_UPDATES";
    /// Virtual MICROseconds winning attempts spent queued between phase
    /// start (every task is ready at enqueue) and dispatch, summed across
    /// the job's plans — the multi-job scheduling item's contention signal.
    pub const QUEUE_WAIT_US: &str = "QUEUE_WAIT_US";
    /// Virtual slot-MICROseconds left unused while the job's phases ran:
    /// makespan × total slots minus attempt occupancy, per plan.
    pub const SLOT_IDLE_US: &str = "SLOT_IDLE_US";
}

impl Counters {
    /// Add `delta` to counter `name`. The hot path (the counter already
    /// exists — every per-record increment after the first) must not
    /// allocate; the `String` key is built only on first touch.
    pub fn incr(&mut self, name: &str, delta: u64) {
        if let Some(v) = self.values.get_mut(name) {
            *v += delta;
        } else {
            self.values.insert(name.to_string(), delta);
        }
    }

    /// Current value (0 when never incremented).
    pub fn get(&self, name: &str) -> u64 {
        self.values.get(name).copied().unwrap_or(0)
    }

    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.values {
            *self.values.entry(k.clone()).or_insert(0) += v;
        }
    }

    /// Iterate (name, value) sorted by name.
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.values.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incr_get_merge() {
        let mut a = Counters::default();
        a.incr("x", 2);
        a.incr("x", 3);
        assert_eq!(a.get("x"), 5);
        assert_eq!(a.get("missing"), 0);
        let mut b = Counters::default();
        b.incr("x", 1);
        b.incr("y", 7);
        a.merge(&b);
        assert_eq!(a.get("x"), 6);
        assert_eq!(a.get("y"), 7);
    }

    #[test]
    fn iter_sorted() {
        let mut c = Counters::default();
        c.incr("b", 1);
        c.incr("a", 1);
        let names: Vec<&str> = c.iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a", "b"]);
    }
}
