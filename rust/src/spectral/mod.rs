//! Spectral clustering: similarity, Laplacian, baseline solvers.
//!
//! The distributed pipeline lives in [`crate::coordinator`]; this module
//! holds the math (shared with the MR jobs) and the single-machine baseline
//! (the O(n³) comparator of paper §4.1).

pub mod laplacian;
pub mod similarity;
pub mod single;

pub use laplacian::{inv_sqrt_degrees, laplacian_dense, laplacian_sparse};
pub use similarity::{adjacency_similarity, gamma_of_sigma, rbf_dense, rbf_sparse};
// The t-NN oracle lives in the knn subsystem but is part of the
// similarity-construction surface alongside rbf_sparse.
pub use crate::knn::tnn_sparse;
pub use single::{
    cluster_embedding, normalize_embedding, spectral_cluster_graph,
    spectral_cluster_points, Eigensolver, SpectralParams, SpectralResult,
};
