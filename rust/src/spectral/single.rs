//! Single-machine spectral clustering (paper Alg. 4.1) — the O(n³)
//! comparator the parallel pipeline is benchmarked against, and the oracle
//! its results are validated against.

use crate::error::Result;
use crate::kmeans::{lloyd, Init};
use crate::linalg::{
    chebdav_smallest, jacobi_eigen, lanczos_smallest, ChebDavOptions, LanczosOptions,
};

use super::laplacian::{laplacian_dense, laplacian_sparse};
use super::similarity::{rbf_dense, rbf_sparse};

/// Which eigensolver the baseline uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Eigensolver {
    /// Dense Jacobi — O(n³), the "traditional" cost the paper cites.
    DenseJacobi,
    /// Lanczos on the sparse Laplacian (single machine, no MapReduce).
    Lanczos,
    /// Block Chebyshev–Davidson on the sparse Laplacian — the oracle for
    /// the distributed chebdav backend (same solver, same block mat-vec).
    ChebDav,
}

/// Parameters of a spectral clustering run.
#[derive(Debug, Clone)]
pub struct SpectralParams {
    /// Number of clusters.
    pub k: usize,
    /// RBF bandwidth.
    pub sigma: f64,
    /// Sparsification threshold.
    pub epsilon: f64,
    /// Similarity-graph construction mode (epsilon threshold | t-NN);
    /// the Lanczos path honors it, dense Jacobi is inherently all-pairs.
    pub graph: crate::knn::GraphMode,
    /// t-NN graph settings (used when `graph` is tnn).
    pub knn: crate::knn::KnnConfig,
    /// Lanczos subspace cap.
    pub lanczos_steps: usize,
    /// K-means iteration cap.
    pub kmeans_iters: usize,
    /// K-means tolerance.
    pub kmeans_tol: f64,
    /// Seed (Lanczos start vector, k-means init).
    pub seed: u64,
    /// ChebDav knobs (block size, filter degree, outer-iteration cap);
    /// only the [`Eigensolver::ChebDav`] path reads them.
    pub eigen: crate::coordinator::eigen::EigenConfig,
}

impl Default for SpectralParams {
    fn default() -> Self {
        let a = crate::config::AlgoConfig::default();
        Self {
            k: a.k,
            sigma: a.sigma,
            epsilon: a.epsilon,
            graph: a.graph,
            knn: crate::knn::KnnConfig::default(),
            lanczos_steps: a.lanczos_steps,
            kmeans_iters: a.kmeans_iters,
            kmeans_tol: a.kmeans_tol,
            seed: a.seed,
            eigen: crate::coordinator::eigen::EigenConfig::default(),
        }
    }
}

/// Output of spectral clustering.
#[derive(Debug, Clone)]
pub struct SpectralResult {
    /// Cluster label per point.
    pub labels: Vec<usize>,
    /// The k smallest Laplacian eigenvalues.
    pub eigenvalues: Vec<f64>,
    /// The row-normalized spectral embedding Y (n × k).
    pub embedding: Vec<Vec<f64>>,
}

/// Row-normalize an n×k embedding (Alg. 4.1 step 5); zero rows stay zero.
pub fn normalize_embedding(z: &mut [Vec<f64>]) {
    for row in z.iter_mut() {
        let norm: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 0.0 {
            for x in row.iter_mut() {
                *x /= norm;
            }
        }
    }
}

/// Cluster the rows of an embedding with k-means (Alg. 4.1 step 6).
pub fn cluster_embedding(
    embedding: &[Vec<f64>],
    k: usize,
    iters: usize,
    tol: f64,
    seed: u64,
) -> Vec<usize> {
    lloyd(embedding, k, iters, tol, Init::PlusPlus, seed).labels
}

/// Full single-machine spectral clustering of a point set.
pub fn spectral_cluster_points(
    points: &[Vec<f64>],
    params: &SpectralParams,
    solver: Eigensolver,
) -> Result<SpectralResult> {
    let n = points.len();
    let (eigenvalues, mut z) = match solver {
        Eigensolver::DenseJacobi => {
            let s = rbf_dense(points, params.sigma);
            let l = laplacian_dense(&s);
            let (vals, vecs) = jacobi_eigen(&l)?;
            let z: Vec<Vec<f64>> = (0..n)
                .map(|i| (0..params.k).map(|c| vecs[(i, c)]).collect())
                .collect();
            (vals[..params.k].to_vec(), z)
        }
        Eigensolver::Lanczos => {
            let s = match params.graph {
                crate::knn::GraphMode::Epsilon => {
                    rbf_sparse(points, params.sigma, params.epsilon)
                }
                crate::knn::GraphMode::Tnn => {
                    crate::knn::tnn_sparse(points, params.sigma, &params.knn)
                }
            };
            let l = laplacian_sparse(&s);
            let opts = LanczosOptions {
                max_steps: params.lanczos_steps.min(n),
                seed: params.seed,
                ..Default::default()
            };
            let r = lanczos_smallest(n, params.k, &opts, |v| l.spmv(v))?;
            (r.eigenvalues, r.eigenvectors)
        }
        Eigensolver::ChebDav => {
            let s = match params.graph {
                crate::knn::GraphMode::Epsilon => {
                    rbf_sparse(points, params.sigma, params.epsilon)
                }
                crate::knn::GraphMode::Tnn => {
                    crate::knn::tnn_sparse(points, params.sigma, &params.knn)
                }
            };
            let l = laplacian_sparse(&s);
            let e = &params.eigen;
            let opts = ChebDavOptions {
                block_size: e.block_size,
                filter_degree: e.filter_degree,
                max_outer: e.max_outer,
                tol: e.residual_tol,
                bound_steps: e.bound_steps,
                seed: params.seed,
            };
            let r = chebdav_smallest(n, params.k, &opts, |x, m| {
                l.spmv_block_rows(x, m, 0, n)
            })?;
            (r.eigenvalues, r.eigenvectors)
        }
    };
    normalize_embedding(&mut z);
    let labels = cluster_embedding(
        &z,
        params.k,
        params.kmeans_iters,
        params.kmeans_tol,
        params.seed,
    );
    Ok(SpectralResult { labels, eigenvalues, embedding: z })
}

/// Spectral clustering of a weighted graph (similarity = adjacency).
pub fn spectral_cluster_graph(
    n: usize,
    adjacency: &[(usize, usize, f64)],
    params: &SpectralParams,
) -> Result<SpectralResult> {
    let s = super::similarity::adjacency_similarity(n, adjacency);
    let l = laplacian_sparse(&s);
    let opts = LanczosOptions {
        max_steps: params.lanczos_steps.min(n),
        seed: params.seed,
        ..Default::default()
    };
    let r = lanczos_smallest(n, params.k, &opts, |v| l.spmv(v))?;
    let mut z = r.eigenvectors;
    normalize_embedding(&mut z);
    let labels = cluster_embedding(
        &z,
        params.k,
        params.kmeans_iters,
        params.kmeans_tol,
        params.seed,
    );
    Ok(SpectralResult { labels, eigenvalues: r.eigenvalues, embedding: z })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gaussian_blobs, planted_graph, two_rings};
    use crate::eval::nmi;

    #[test]
    fn blobs_both_solvers_agree_with_truth() {
        let ps = gaussian_blobs(120, 3, 2, 0.3, 12.0, 3);
        let params = SpectralParams { k: 3, sigma: 2.0, ..Default::default() };
        for solver in [Eigensolver::DenseJacobi, Eigensolver::Lanczos] {
            let r = spectral_cluster_points(&ps.points, &params, solver).unwrap();
            let score = nmi(&ps.labels, &r.labels);
            assert!(score > 0.95, "{solver:?}: nmi={score}");
        }
    }

    #[test]
    fn chebdav_oracle_agrees_with_lanczos_on_blobs() {
        let ps = gaussian_blobs(120, 3, 2, 0.3, 12.0, 3);
        let params = SpectralParams {
            k: 3,
            sigma: 2.0,
            eigen: crate::coordinator::eigen::EigenConfig {
                max_outer: 12,
                ..Default::default()
            },
            ..Default::default()
        };
        let cd =
            spectral_cluster_points(&ps.points, &params, Eigensolver::ChebDav).unwrap();
        let lz =
            spectral_cluster_points(&ps.points, &params, Eigensolver::Lanczos).unwrap();
        assert!(nmi(&ps.labels, &cd.labels) > 0.95, "chebdav oracle quality");
        // Both solvers see the same Laplacian; the smallest eigenvalue of
        // L_sym is 0 and the spectra must agree to solver tolerance.
        assert!(cd.eigenvalues[0].abs() < 1e-6, "{:?}", cd.eigenvalues);
        for (a, b) in cd.eigenvalues.iter().zip(&lz.eigenvalues) {
            assert!((a - b).abs() < 1e-4, "chebdav {a} vs lanczos {b}");
        }
    }

    #[test]
    fn rings_solved_by_spectral_not_kmeans() {
        // The paper's core motivation (§3.1): arbitrary-shape clusters.
        let ps = two_rings(240, 1.0, 6.0, 0.08, 3);
        let params = SpectralParams {
            k: 2,
            sigma: 0.5,
            lanczos_steps: 80,
            ..Default::default()
        };
        let r =
            spectral_cluster_points(&ps.points, &params, Eigensolver::Lanczos).unwrap();
        let spectral_score = nmi(&ps.labels, &r.labels);
        let km = crate::kmeans::lloyd(
            &ps.points, 2, 100, 1e-9, crate::kmeans::Init::PlusPlus, 5,
        );
        let kmeans_score = nmi(&ps.labels, &km.labels);
        assert!(
            spectral_score > 0.9,
            "spectral should solve rings: {spectral_score}"
        );
        assert!(
            spectral_score > kmeans_score + 0.5,
            "spectral {spectral_score} vs kmeans {kmeans_score}"
        );
    }

    #[test]
    fn tnn_graph_mode_recovers_blobs() {
        // The single-machine t-NN path: same clustering quality as the
        // epsilon path on well-separated blobs, far fewer stored entries.
        let ps = gaussian_blobs(150, 3, 4, 0.3, 10.0, 3);
        let params = SpectralParams {
            k: 3,
            sigma: 1.5,
            graph: crate::knn::GraphMode::Tnn,
            knn: crate::knn::KnnConfig { t: 8, ..Default::default() },
            // Well-separated blobs give an exactly-disconnected t-NN graph
            // (a 0 eigenvalue of multiplicity k): a full-dimension Krylov
            // space resolves the multiplicity deterministically.
            lanczos_steps: 150,
            ..Default::default()
        };
        let r =
            spectral_cluster_points(&ps.points, &params, Eigensolver::Lanczos).unwrap();
        let score = nmi(&ps.labels, &r.labels);
        assert!(score > 0.95, "tnn-mode nmi={score}");
        let s = crate::knn::tnn_sparse(&ps.points, 1.5, &params.knn);
        let dense_nnz = 150usize * 150;
        assert!(s.nnz() * 4 < dense_nnz, "t-NN graph should be sparse");
    }

    #[test]
    fn planted_graph_communities_recovered() {
        let topo = planted_graph(200, 600, 4, 0.02, 7);
        let r = spectral_cluster_graph(
            200,
            &topo.adjacency_triplets(),
            &SpectralParams { k: 4, lanczos_steps: 80, ..Default::default() },
        )
        .unwrap();
        let score = nmi(&topo.labels(), &r.labels);
        assert!(score > 0.8, "community recovery nmi={score}");
    }

    #[test]
    fn embedding_rows_unit_norm() {
        let ps = gaussian_blobs(60, 2, 2, 0.3, 10.0, 1);
        let r = spectral_cluster_points(
            &ps.points,
            &SpectralParams { k: 2, ..Default::default() },
            Eigensolver::Lanczos,
        )
        .unwrap();
        for row in &r.embedding {
            let n: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-9 || n == 0.0, "row norm {n}");
        }
    }

    #[test]
    fn smallest_eigenvalue_near_zero() {
        let ps = gaussian_blobs(80, 2, 2, 0.3, 10.0, 5);
        let r = spectral_cluster_points(
            &ps.points,
            &SpectralParams { k: 2, ..Default::default() },
            Eigensolver::Lanczos,
        )
        .unwrap();
        // lambda_1(L_sym) = 0 always.
        assert!(r.eigenvalues[0].abs() < 1e-8, "{:?}", r.eigenvalues);
    }
}
