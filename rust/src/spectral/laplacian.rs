//! Normalized symmetric Laplacian L_sym = I − D^{-1/2} S D^{-1/2}.
//!
//! The paper's Alg. 4.1 step 3 writes `L = D^{-1/2} S D^{-1/2}` and then
//! asks for the *k smallest* eigenvectors — consistent with L_sym (the k
//! smallest of L_sym correspond to the k largest of the paper's normalized
//! matrix; identical eigenvectors). DESIGN.md §7 records the convention.

use crate::linalg::{CsrMatrix, DenseMatrix};

/// d^{-1/2} per row of a similarity matrix (0 where the degree is 0).
pub fn inv_sqrt_degrees(s: &CsrMatrix) -> Vec<f64> {
    s.row_sums()
        .into_iter()
        .map(|d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect()
}

/// Sparse L_sym from a sparse similarity matrix.
pub fn laplacian_sparse(s: &CsrMatrix) -> CsrMatrix {
    let n = s.rows();
    let dinv = inv_sqrt_degrees(s);
    let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    for i in 0..n {
        let mut row: Vec<(u32, f64)> = Vec::new();
        let mut has_diag = false;
        for (j, v) in s.row(i) {
            let ju = j as usize;
            let mut val = -dinv[i] * v * dinv[ju];
            if ju == i {
                val += 1.0;
                has_diag = true;
            }
            row.push((j, val));
        }
        if !has_diag {
            row.push((i as u32, 1.0));
        }
        rows[i] = row;
    }
    CsrMatrix::from_rows(n, rows)
}

/// Dense L_sym (baseline path).
pub fn laplacian_dense(s: &DenseMatrix) -> DenseMatrix {
    let n = s.rows();
    let degrees: Vec<f64> = (0..n).map(|i| s.row(i).iter().sum()).collect();
    let dinv: Vec<f64> = degrees
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();
    let mut l = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let eye = if i == j { 1.0 } else { 0.0 };
            l[(i, j)] = eye - dinv[i] * s[(i, j)] * dinv[j];
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::jacobi_eigen;

    fn block_similarity() -> CsrMatrix {
        // Two disconnected cliques of 3 (unit weights + unit diagonal).
        let mut trips = vec![];
        for base in [0usize, 3] {
            for a in 0..3 {
                for b in 0..3 {
                    trips.push((base + a, base + b, 1.0));
                }
            }
        }
        CsrMatrix::from_triplets(6, 6, &trips).unwrap()
    }

    #[test]
    fn sparse_dense_agree() {
        let s = block_similarity();
        let ls = laplacian_sparse(&s);
        let ld = laplacian_dense(&s.to_dense());
        assert!(ls.to_dense().max_abs_diff(&ld) < 1e-12);
    }

    #[test]
    fn laplacian_symmetric_psd() {
        let s = block_similarity();
        let l = laplacian_sparse(&s).to_dense();
        assert!(l.is_symmetric(1e-12));
        let (vals, _) = jacobi_eigen(&l).unwrap();
        assert!(vals[0] > -1e-10, "L_sym is PSD: {vals:?}");
        // Normalized Laplacian eigenvalues are <= 2.
        assert!(*vals.last().unwrap() <= 2.0 + 1e-10);
    }

    #[test]
    fn zero_eigenvalue_multiplicity_counts_components() {
        let s = block_similarity();
        let l = laplacian_sparse(&s).to_dense();
        let (vals, _) = jacobi_eigen(&l).unwrap();
        // Two connected components -> two (near-)zero eigenvalues (§3.2.2).
        assert!(vals[0].abs() < 1e-10);
        assert!(vals[1].abs() < 1e-10);
        assert!(vals[2] > 0.5, "spectral gap: {vals:?}");
    }

    #[test]
    fn isolated_vertex_handled() {
        // Vertex 2 has no edges and no self-loop: degree 0.
        let s = CsrMatrix::from_triplets(3, 3, &[(0, 1, 1.0), (1, 0, 1.0)]).unwrap();
        let l = laplacian_sparse(&s);
        assert_eq!(l.get(2, 2), 1.0, "isolated vertex gets unit diagonal");
        assert_eq!(l.rows(), 3);
    }
}
