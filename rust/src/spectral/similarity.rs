//! Similarity matrix construction (paper §3.2.3 / Alg. 4.1 step 1).
//!
//! `S_ij = exp(-||x_i - x_j||² / 2σ²)`, then sparsified: entries below
//! `epsilon` are dropped ("and then sparse it"). The single-machine versions
//! here are the oracles the distributed phase-1 job is tested against.

use crate::linalg::kernels::{self, ScanSink};
use crate::linalg::{CsrMatrix, DenseMatrix};

/// gamma = 1 / (2 sigma²) — the exponent factor the kernels take.
pub fn gamma_of_sigma(sigma: f64) -> f64 {
    1.0 / (2.0 * sigma * sigma)
}

/// Dense RBF similarity matrix (O(n² d), baseline only).
pub fn rbf_dense(points: &[Vec<f64>], sigma: f64) -> DenseMatrix {
    let n = points.len();
    let gamma = gamma_of_sigma(sigma);
    let mut s = DenseMatrix::zeros(n, n);
    for i in 0..n {
        s[(i, i)] = 1.0;
        for j in (i + 1)..n {
            let d2 = crate::linalg::vector::sq_dist(&points[i], &points[j]);
            let v = (-gamma * d2).exp();
            s[(i, j)] = v;
            s[(j, i)] = v;
        }
    }
    s
}

/// Sparse RBF similarity: entries < `epsilon` dropped (diagonal kept).
///
/// Two prunes keep the epsilon path honest at scale: row vectors are
/// pre-sized from a sampled degree estimate instead of growing from empty,
/// and each pair's distance scan — routed through the blocked distance
/// kernel ([`kernels::sq_dist_scan_range`]) — aborts early once the
/// running total already implies `v < epsilon` (`d2 > -ln(epsilon)/gamma`
/// ⇒ dropped either way; the bound is fixed per run, so the kernel
/// classifies exactly like the scalar scan and surviving entries are
/// bit-identical to it).
pub fn rbf_sparse(points: &[Vec<f64>], sigma: f64, epsilon: f64) -> CsrMatrix {
    let n = points.len();
    if n == 0 {
        return CsrMatrix::from_rows(0, Vec::new());
    }
    let gamma = gamma_of_sigma(sigma);
    // Slack on the abort bound keeps boundary rounding on the safe side.
    let d2_bound = if epsilon > 0.0 {
        (-epsilon.ln() / gamma) * (1.0 + 1e-9)
    } else {
        f64::INFINITY
    };
    let est = estimated_degree(points, d2_bound);
    let d = points[0].len();
    let flat: Vec<f64> = points.iter().flatten().copied().collect();

    /// Sink for row `i`'s upper-triangle scan: weight survivors land in
    /// both row `i` and the mirrored row `j`.
    struct RowSink<'a> {
        rows: &'a mut Vec<Vec<(u32, f64)>>,
        i: usize,
        gamma: f64,
        epsilon: f64,
        d2_bound: f64,
    }

    impl ScanSink for RowSink<'_> {
        fn bound(&self) -> f64 {
            self.d2_bound
        }

        fn emit(&mut self, j: u32, d2: Option<f64>) {
            let Some(d2) = d2 else { return };
            let v = (-self.gamma * d2).exp();
            if v >= self.epsilon {
                self.rows[self.i].push((j, v));
                self.rows[j as usize].push((self.i as u32, v));
            }
        }
    }

    let mut rows: Vec<Vec<(u32, f64)>> =
        (0..n).map(|_| Vec::with_capacity(est + 1)).collect();
    for i in 0..n {
        rows[i].push((i as u32, 1.0));
        let mut sink = RowSink { rows: &mut rows, i, gamma, epsilon, d2_bound };
        kernels::sq_dist_scan_range(
            &flat[i * d..(i + 1) * d],
            &flat,
            d,
            (i + 1) as u32,
            n as u32,
            None,
            &mut sink,
        );
    }
    CsrMatrix::from_rows(n, rows)
}

/// Estimated neighbors per row: the in-bound fraction of a deterministic
/// pair sample, scaled to n−1. Only has to be the right order of
/// magnitude — it sizes the row reserves, nothing else.
fn estimated_degree(points: &[Vec<f64>], d2_bound: f64) -> usize {
    let n = points.len();
    if n < 2 || d2_bound == f64::INFINITY {
        return n.saturating_sub(1);
    }
    let mut rng = crate::util::rng::Xoshiro256::new(0x5eed_de9);
    let samples = (n * (n - 1) / 2).min(256);
    let mut kept = 0usize;
    let mut seen = 0usize;
    while seen < samples {
        let i = (rng.next_u64() % n as u64) as usize;
        let j = (rng.next_u64() % n as u64) as usize;
        if i == j {
            continue;
        }
        seen += 1;
        if crate::linalg::vector::sq_dist_bounded(&points[i], &points[j], d2_bound)
            .is_some()
        {
            kept += 1;
        }
    }
    (kept as f64 / samples as f64 * (n - 1) as f64).ceil() as usize
}

/// Similarity from a weighted graph adjacency (graph-input mode): the edge
/// weight IS the similarity; unit diagonal added so no degree vanishes.
pub fn adjacency_similarity(n: usize, triplets: &[(usize, usize, f64)]) -> CsrMatrix {
    let mut all: Vec<(usize, usize, f64)> = triplets.to_vec();
    for i in 0..n {
        all.push((i, i, 1.0));
    }
    CsrMatrix::from_triplets(n, n, &all).expect("adjacency triplets in range")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<Vec<f64>> {
        vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![10.0, 10.0]]
    }

    #[test]
    fn dense_matches_formula() {
        let s = rbf_dense(&pts(), 1.0);
        assert_eq!(s[(0, 0)], 1.0);
        assert!((s[(0, 1)] - (-0.5f64).exp()).abs() < 1e-12);
        assert_eq!(s[(0, 1)], s[(1, 0)]);
        assert!(s[(0, 2)] < 1e-40, "far points ~0 similarity");
        assert!(s.is_symmetric(0.0));
    }

    #[test]
    fn sigma_controls_bandwidth() {
        let narrow = rbf_dense(&pts(), 0.3);
        let wide = rbf_dense(&pts(), 3.0);
        assert!(narrow[(0, 1)] < wide[(0, 1)]);
    }

    #[test]
    fn sparse_drops_small_entries_keeps_diag() {
        let s = rbf_sparse(&pts(), 1.0, 1e-3);
        assert_eq!(s.get(0, 0), 1.0);
        assert!(s.get(0, 1) > 0.0);
        assert_eq!(s.get(0, 2), 0.0, "tiny entry dropped");
        assert!(s.is_symmetric(1e-15));
        // Dense and sparse agree on surviving entries.
        let d = rbf_dense(&pts(), 1.0);
        assert!((s.get(0, 1) - d[(0, 1)]).abs() < 1e-15);
    }

    #[test]
    fn sparse_early_exit_is_output_neutral() {
        // The pre-sizing + partial-distance abort must not change a single
        // bit of what survives, across loose and harsh thresholds.
        let ps = crate::data::gaussian_blobs(120, 3, 4, 0.4, 8.0, 2);
        let d = rbf_dense(&ps.points, 1.0);
        for eps in [1e-8, 1e-3, 0.5] {
            let s = rbf_sparse(&ps.points, 1.0, eps);
            let mut nnz = 0usize;
            for i in 0..120 {
                for j in 0..120 {
                    let v = d[(i, j)];
                    if i == j || v >= eps {
                        assert_eq!(
                            s.get(i, j).to_bits(),
                            v.to_bits(),
                            "({i},{j}) eps={eps}"
                        );
                        nnz += 1;
                    } else {
                        assert_eq!(s.get(i, j), 0.0, "({i},{j}) eps={eps}");
                    }
                }
            }
            assert_eq!(s.nnz(), nnz, "eps={eps}");
        }
    }

    #[test]
    fn adjacency_similarity_symmetric_with_diag() {
        let s = adjacency_similarity(3, &[(0, 1, 2.0), (1, 0, 2.0)]);
        assert_eq!(s.get(0, 1), 2.0);
        assert_eq!(s.get(1, 0), 2.0);
        assert_eq!(s.get(2, 2), 1.0);
        assert!(s.is_symmetric(0.0));
    }
}
