//! Similarity matrix construction (paper §3.2.3 / Alg. 4.1 step 1).
//!
//! `S_ij = exp(-||x_i - x_j||² / 2σ²)`, then sparsified: entries below
//! `epsilon` are dropped ("and then sparse it"). The single-machine versions
//! here are the oracles the distributed phase-1 job is tested against.

use crate::linalg::{CsrMatrix, DenseMatrix};

/// gamma = 1 / (2 sigma²) — the exponent factor the kernels take.
pub fn gamma_of_sigma(sigma: f64) -> f64 {
    1.0 / (2.0 * sigma * sigma)
}

/// Dense RBF similarity matrix (O(n² d), baseline only).
pub fn rbf_dense(points: &[Vec<f64>], sigma: f64) -> DenseMatrix {
    let n = points.len();
    let gamma = gamma_of_sigma(sigma);
    let mut s = DenseMatrix::zeros(n, n);
    for i in 0..n {
        s[(i, i)] = 1.0;
        for j in (i + 1)..n {
            let d2 = crate::linalg::vector::sq_dist(&points[i], &points[j]);
            let v = (-gamma * d2).exp();
            s[(i, j)] = v;
            s[(j, i)] = v;
        }
    }
    s
}

/// Sparse RBF similarity: entries < `epsilon` dropped (diagonal kept).
pub fn rbf_sparse(points: &[Vec<f64>], sigma: f64, epsilon: f64) -> CsrMatrix {
    let n = points.len();
    let gamma = gamma_of_sigma(sigma);
    let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
    for i in 0..n {
        rows[i].push((i as u32, 1.0));
        for j in (i + 1)..n {
            let d2 = crate::linalg::vector::sq_dist(&points[i], &points[j]);
            let v = (-gamma * d2).exp();
            if v >= epsilon {
                rows[i].push((j as u32, v));
                rows[j].push((i as u32, v));
            }
        }
    }
    CsrMatrix::from_rows(n, rows)
}

/// Similarity from a weighted graph adjacency (graph-input mode): the edge
/// weight IS the similarity; unit diagonal added so no degree vanishes.
pub fn adjacency_similarity(n: usize, triplets: &[(usize, usize, f64)]) -> CsrMatrix {
    let mut all: Vec<(usize, usize, f64)> = triplets.to_vec();
    for i in 0..n {
        all.push((i, i, 1.0));
    }
    CsrMatrix::from_triplets(n, n, &all).expect("adjacency triplets in range")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Vec<Vec<f64>> {
        vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![10.0, 10.0]]
    }

    #[test]
    fn dense_matches_formula() {
        let s = rbf_dense(&pts(), 1.0);
        assert_eq!(s[(0, 0)], 1.0);
        assert!((s[(0, 1)] - (-0.5f64).exp()).abs() < 1e-12);
        assert_eq!(s[(0, 1)], s[(1, 0)]);
        assert!(s[(0, 2)] < 1e-40, "far points ~0 similarity");
        assert!(s.is_symmetric(0.0));
    }

    #[test]
    fn sigma_controls_bandwidth() {
        let narrow = rbf_dense(&pts(), 0.3);
        let wide = rbf_dense(&pts(), 3.0);
        assert!(narrow[(0, 1)] < wide[(0, 1)]);
    }

    #[test]
    fn sparse_drops_small_entries_keeps_diag() {
        let s = rbf_sparse(&pts(), 1.0, 1e-3);
        assert_eq!(s.get(0, 0), 1.0);
        assert!(s.get(0, 1) > 0.0);
        assert_eq!(s.get(0, 2), 0.0, "tiny entry dropped");
        assert!(s.is_symmetric(1e-15));
        // Dense and sparse agree on surviving entries.
        let d = rbf_dense(&pts(), 1.0);
        assert!((s.get(0, 1) - d[(0, 1)]).abs() < 1e-15);
    }

    #[test]
    fn adjacency_similarity_symmetric_with_diag() {
        let s = adjacency_similarity(3, &[(0, 1, 2.0), (1, 0, 2.0)]);
        assert_eq!(s.get(0, 1), 2.0);
        assert_eq!(s.get(1, 0), 2.0);
        assert_eq!(s.get(2, 2), 1.0);
        assert!(s.is_symmetric(0.0));
    }
}
