//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. The variants
//! mirror the subsystems: DFS, table store, MapReduce engine, XLA runtime,
//! linear algebra, data parsing and configuration.

use thiserror::Error;

/// Crate-wide error enum.
#[derive(Debug, Error)]
pub enum Error {
    /// Mini-HDFS failures (missing file/block, replication impossible, ...).
    #[error("dfs: {0}")]
    Dfs(String),

    /// Mini-HBase failures (missing table/row, region errors, ...).
    #[error("table: {0}")]
    Table(String),

    /// MapReduce engine failures (task failed after retries, bad job conf).
    #[error("mapreduce: {0}")]
    MapReduce(String),

    /// XLA/PJRT runtime failures (artifact missing, shape mismatch, ...).
    #[error("runtime: {0}")]
    Runtime(String),

    /// Linear-algebra failures (non-convergence, dimension mismatch).
    #[error("linalg: {0}")]
    Linalg(String),

    /// Data-format failures (topology file parse errors, ...).
    #[error("data: {0}")]
    Data(String),

    /// Configuration errors (bad key, invalid value, validation failure).
    #[error("config: {0}")]
    Config(String),

    /// CLI usage errors.
    #[error("cli: {0}")]
    Cli(String),

    /// I/O errors bubbling up from std.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Errors from the `xla` crate (PJRT client / compile / execute).
    #[error("xla: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_subsystem() {
        let e = Error::Dfs("file not found".into());
        assert_eq!(e.to_string(), "dfs: file not found");
        let e = Error::MapReduce("task 3 failed".into());
        assert!(e.to_string().starts_with("mapreduce:"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
