//! Crate-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. The variants
//! mirror the subsystems: DFS, table store, MapReduce engine, XLA runtime,
//! linear algebra, data parsing and configuration. Display/Error are
//! hand-implemented — the offline build has no derive-macro crates.

use std::fmt;

/// Crate-wide error enum.
#[derive(Debug)]
pub enum Error {
    /// Mini-HDFS failures (missing file/block, replication impossible, ...).
    Dfs(String),

    /// Mini-HBase failures (missing table/row, region errors, ...).
    Table(String),

    /// MapReduce engine failures (task failed after retries, bad job conf).
    MapReduce(String),

    /// XLA/PJRT runtime failures (artifact missing, shape mismatch, ...).
    Runtime(String),

    /// Linear-algebra failures (non-convergence, dimension mismatch).
    Linalg(String),

    /// Data-format failures (topology file parse errors, ...).
    Data(String),

    /// Configuration errors (bad key, invalid value, validation failure).
    Config(String),

    /// CLI usage errors.
    Cli(String),

    /// I/O errors bubbling up from std.
    Io(std::io::Error),

    /// Errors from the `xla` crate (PJRT client / compile / execute).
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Dfs(m) => write!(f, "dfs: {m}"),
            Error::Table(m) => write!(f, "table: {m}"),
            Error::MapReduce(m) => write!(f, "mapreduce: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Linalg(m) => write!(f, "linalg: {m}"),
            Error::Data(m) => write!(f, "data: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Cli(m) => write!(f, "cli: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_subsystem() {
        let e = Error::Dfs("file not found".into());
        assert_eq!(e.to_string(), "dfs: file not found");
        let e = Error::MapReduce("task 3 failed".into());
        assert!(e.to_string().starts_with("mapreduce:"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
