//! Configuration system: cluster + algorithm settings.
//!
//! Config files are a TOML subset (`key = value` lines, `[section]` headers,
//! `#` comments) parsed in-tree — the offline vendor set has no serde/toml.
//! Every key can also be overridden from the CLI (`--set section.key=value`).


use crate::cluster::{FaultConfig, NetworkModel, NodeDeath};
use crate::coordinator::eigen::{EigenConfig, EigenSolverKind};
use crate::error::{Error, Result};
use crate::knn::{GraphMode, IndexKind, KnnConfig};
use crate::mapreduce::ShuffleConfig;
use crate::scheduler::{Policy, SpeculationConfig};
use crate::serving::{RefreshMode, ServingConfig};

/// The RBF bandwidth setting: an explicit value, or `"auto"` — resolved by
/// the driver to the mean t-th-neighbor distance of the input point set
/// (the 1802.04450 heuristic, using the `[knn]` index and `knn.t`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SigmaSpec {
    /// Explicit bandwidth (`algo.sigma = 1.5`).
    Fixed(f64),
    /// Resolve from the t-NN distance distribution (`algo.sigma = "auto"`).
    Auto,
}

impl SigmaSpec {
    /// Parse a config/CLI value: `"auto"` or a float literal.
    pub fn parse(s: &str) -> Option<Self> {
        if s == "auto" {
            Some(Self::Auto)
        } else {
            s.parse().ok().map(Self::Fixed)
        }
    }

    /// The explicit bandwidth, when there is one.
    pub fn fixed(&self) -> Option<f64> {
        match self {
            Self::Fixed(v) => Some(*v),
            Self::Auto => None,
        }
    }

    /// True for the auto-tuned setting.
    pub fn is_auto(&self) -> bool {
        matches!(self, Self::Auto)
    }
}

impl From<f64> for SigmaSpec {
    fn from(v: f64) -> Self {
        Self::Fixed(v)
    }
}

/// Cluster-side settings.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of slave machines (paper sweeps 1..10).
    pub slaves: usize,
    /// Map/reduce slots per slave (paper: 2).
    pub slots_per_slave: usize,
    /// DFS replication factor.
    pub replication: usize,
    /// Racks the slaves are spread over (contiguous groups; clamped to
    /// the slave count).
    pub racks: usize,
    /// JobTracker slot-filling policy.
    pub scheduler: Policy,
    /// Delay-scheduling heartbeats, remembered independently of the active
    /// policy so `scheduler` / `locality_delay` keys commute in any order.
    pub locality_delay: usize,
    /// Virtual seconds between slave heartbeats.
    pub heartbeat_s: f64,
    /// Speculative-execution knobs.
    pub speculation: SpeculationConfig,
    /// Cost model.
    pub network: NetworkModel,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            slaves: 4,
            slots_per_slave: 2,
            replication: 2,
            racks: 1,
            scheduler: Policy::default(),
            locality_delay: 2,
            heartbeat_s: 3.0,
            speculation: SpeculationConfig::default(),
            network: NetworkModel::default(),
        }
    }
}

/// Algorithm-side settings.
#[derive(Debug, Clone, PartialEq)]
pub struct AlgoConfig {
    /// Number of clusters k.
    pub k: usize,
    /// RBF bandwidth sigma (paper §3.2.3), or `"auto"` for the mean
    /// t-th-neighbor-distance heuristic.
    pub sigma: SigmaSpec,
    /// Similarity sparsification threshold (entries below are dropped).
    pub epsilon: f64,
    /// How phase 1 sparsifies: epsilon post-filter or t-NN construction
    /// (`[knn]` section holds the t-NN knobs).
    pub graph: GraphMode,
    /// Lanczos max steps m.
    pub lanczos_steps: usize,
    /// K-means max iterations.
    pub kmeans_iters: usize,
    /// K-means convergence tolerance on center movement.
    pub kmeans_tol: f64,
    /// RNG seed for init / data generation.
    pub seed: u64,
}

impl Default for AlgoConfig {
    fn default() -> Self {
        Self {
            k: 4,
            sigma: SigmaSpec::Fixed(1.0),
            epsilon: 1e-8,
            graph: GraphMode::Epsilon,
            lanczos_steps: 60,
            kmeans_iters: 20,
            kmeans_tol: 1e-6,
            seed: 42,
        }
    }
}

/// Full configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    /// Cluster settings (`[cluster]` section).
    pub cluster: ClusterConfig,
    /// Shuffle settings (`[shuffle]` section): sort buffer, merge factor,
    /// fetch parallelism (Hadoop's `io.sort.*` family).
    pub shuffle: ShuffleConfig,
    /// Failure-domain settings (`[faults]` section): seeded per-attempt
    /// failure probability, scheduled node deaths, blacklisting and the
    /// per-task attempt budget. See `configs/chaos.toml`.
    pub faults: FaultConfig,
    /// t-NN similarity-graph settings (`[knn]` section), active when
    /// `algo.graph = "tnn"`.
    pub knn: KnnConfig,
    /// Algorithm settings (`[algo]` section).
    pub algo: AlgoConfig,
    /// Eigen-phase settings (`[eigen]` section): solver backend selector
    /// plus the ChebDav block/filter knobs. `algo.eigensolver` is accepted
    /// as an alias for `eigen.solver`.
    pub eigen: EigenConfig,
    /// Serving-layer settings (`[serving]` section): landmark budget for
    /// the persisted model artifact, assign batch size, and the mini-batch
    /// centroid refresh mode (`psch run --model-out` / `psch assign`).
    pub serving: ServingConfig,
}

impl Config {
    /// Parse from TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        for (key, value) in parse_kv(text)? {
            cfg.set(&key, &value)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    /// Apply one `section.key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad_val =
            |k: &str| Error::Config(format!("bad value for {k}: {value:?}"));
        match key {
            "cluster.slaves" => {
                self.cluster.slaves = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.slots_per_slave" => {
                self.cluster.slots_per_slave = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.replication" => {
                self.cluster.replication = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.racks" => {
                self.cluster.racks = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.scheduler" => {
                // Switching to locality picks up whatever delay was set by
                // cluster.locality_delay, whichever key came first; an
                // explicit fifo is never silently overridden by the delay.
                self.cluster.scheduler =
                    match Policy::parse(value).ok_or_else(|| bad_val(key))? {
                        Policy::Fifo => Policy::Fifo,
                        Policy::LocalityAware { .. } => Policy::LocalityAware {
                            locality_delay: self.cluster.locality_delay,
                        },
                    };
            }
            "cluster.locality_delay" => {
                let delay = value.parse().map_err(|_| bad_val(key))?;
                self.cluster.locality_delay = delay;
                if let Policy::LocalityAware { locality_delay } =
                    &mut self.cluster.scheduler
                {
                    *locality_delay = delay;
                }
            }
            "cluster.heartbeat_s" => {
                self.cluster.heartbeat_s = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.speculation" => {
                self.cluster.speculation.enabled =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.speculative_slowdown" => {
                self.cluster.speculation.slowdown =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.rack_bw" => {
                self.cluster.network.rack_bw = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.cross_rack_bw" => {
                self.cluster.network.cross_rack_bw =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.job_setup_s" => {
                self.cluster.network.job_setup_s =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.task_dispatch_s" => {
                self.cluster.network.task_dispatch_s =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.disk_bw" => {
                self.cluster.network.disk_bw = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.net_bw" => {
                self.cluster.network.net_bw = value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.coord_per_machine_s" => {
                self.cluster.network.coord_per_machine_s =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.shuffle_latency_s" => {
                self.cluster.network.shuffle_latency_s =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "cluster.compute_scale" => {
                self.cluster.network.compute_scale =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "shuffle.sort_buffer_kb" => {
                self.shuffle.sort_buffer_kb = value.parse().map_err(|_| bad_val(key))?
            }
            "shuffle.merge_factor" => {
                self.shuffle.merge_factor = value.parse().map_err(|_| bad_val(key))?
            }
            "shuffle.fetch_parallelism" => {
                self.shuffle.fetch_parallelism =
                    value.parse().map_err(|_| bad_val(key))?
            }
            "faults.seed" => {
                self.faults.seed = value.parse().map_err(|_| bad_val(key))?
            }
            "faults.task_fail_prob" => {
                self.faults.task_fail_prob = value.parse().map_err(|_| bad_val(key))?
            }
            "faults.max_attempts" => {
                self.faults.max_attempts = value.parse().map_err(|_| bad_val(key))?
            }
            "faults.blacklist_after" => {
                self.faults.blacklist_after = value.parse().map_err(|_| bad_val(key))?
            }
            "faults.fail_node" => {
                // Comma-separated `<slave>@<heartbeat>` deaths; an empty
                // value clears the schedule.
                let mut deaths = Vec::new();
                for part in value.split(',').filter(|p| !p.trim().is_empty()) {
                    deaths.push(NodeDeath::parse(part).ok_or_else(|| {
                        Error::Config(format!(
                            "faults.fail_node wants <slave>@<heartbeat>, got {part:?}"
                        ))
                    })?);
                }
                self.faults.node_deaths = deaths;
            }
            "knn.t" => self.knn.t = value.parse().map_err(|_| bad_val(key))?,
            "knn.leaf_size" => {
                self.knn.leaf_size = value.parse().map_err(|_| bad_val(key))?
            }
            "knn.index" => {
                self.knn.index = IndexKind::parse(value).ok_or_else(|| bad_val(key))?
            }
            "algo.k" => self.algo.k = value.parse().map_err(|_| bad_val(key))?,
            "algo.graph" => {
                self.algo.graph = GraphMode::parse(value).ok_or_else(|| bad_val(key))?
            }
            "algo.sigma" => {
                self.algo.sigma = SigmaSpec::parse(value).ok_or_else(|| bad_val(key))?
            }
            "algo.epsilon" => {
                self.algo.epsilon = value.parse().map_err(|_| bad_val(key))?
            }
            "algo.lanczos_steps" => {
                self.algo.lanczos_steps = value.parse().map_err(|_| bad_val(key))?
            }
            "algo.kmeans_iters" => {
                self.algo.kmeans_iters = value.parse().map_err(|_| bad_val(key))?
            }
            "algo.kmeans_tol" => {
                self.algo.kmeans_tol = value.parse().map_err(|_| bad_val(key))?
            }
            "algo.seed" => self.algo.seed = value.parse().map_err(|_| bad_val(key))?,
            // `algo.eigensolver` is the paper-facing spelling; it aliases
            // the `[eigen]` section's backend selector.
            "eigen.solver" | "algo.eigensolver" => {
                self.eigen.solver =
                    EigenSolverKind::parse(value).ok_or_else(|| bad_val(key))?
            }
            "eigen.block_size" => {
                self.eigen.block_size = value.parse().map_err(|_| bad_val(key))?
            }
            "eigen.filter_degree" => {
                self.eigen.filter_degree = value.parse().map_err(|_| bad_val(key))?
            }
            "eigen.max_outer" => {
                self.eigen.max_outer = value.parse().map_err(|_| bad_val(key))?
            }
            "eigen.residual_tol" => {
                self.eigen.residual_tol = value.parse().map_err(|_| bad_val(key))?
            }
            "eigen.bound_steps" => {
                self.eigen.bound_steps = value.parse().map_err(|_| bad_val(key))?
            }
            "serving.landmarks" => {
                self.serving.landmarks = value.parse().map_err(|_| bad_val(key))?
            }
            "serving.batch_points" => {
                self.serving.batch_points = value.parse().map_err(|_| bad_val(key))?
            }
            "serving.refresh" => {
                self.serving.refresh =
                    RefreshMode::parse(value).ok_or_else(|| bad_val(key))?
            }
            other => {
                return Err(Error::Config(format!("unknown config key: {other}")))
            }
        }
        Ok(())
    }

    /// Sanity-check values.
    pub fn validate(&self) -> Result<()> {
        let bad = |msg: String| Err(Error::Config(msg));
        if self.cluster.slaves == 0 {
            return bad("cluster.slaves must be >= 1".into());
        }
        if self.cluster.slots_per_slave == 0 {
            return bad("cluster.slots_per_slave must be >= 1".into());
        }
        if self.cluster.racks == 0 {
            return bad("cluster.racks must be >= 1".into());
        }
        if self.cluster.heartbeat_s <= 0.0 {
            return bad(format!(
                "cluster.heartbeat_s must be > 0, got {}",
                self.cluster.heartbeat_s
            ));
        }
        if self.cluster.speculation.slowdown < 1.0 {
            return bad(format!(
                "cluster.speculative_slowdown must be >= 1, got {}",
                self.cluster.speculation.slowdown
            ));
        }
        if self.shuffle.sort_buffer_kb == 0 {
            return bad("shuffle.sort_buffer_kb must be >= 1".into());
        }
        if self.shuffle.merge_factor < 2 {
            return bad(format!(
                "shuffle.merge_factor must be >= 2, got {}",
                self.shuffle.merge_factor
            ));
        }
        if self.shuffle.fetch_parallelism == 0 {
            return bad("shuffle.fetch_parallelism must be >= 1".into());
        }
        if !(0.0..1.0).contains(&self.faults.task_fail_prob) {
            return bad(format!(
                "faults.task_fail_prob must be in [0, 1), got {}",
                self.faults.task_fail_prob
            ));
        }
        if self.faults.max_attempts == 0 {
            return bad("faults.max_attempts must be >= 1".into());
        }
        if self.faults.blacklist_after == 0 {
            return bad("faults.blacklist_after must be >= 1".into());
        }
        for d in &self.faults.node_deaths {
            if d.slave >= self.cluster.slaves {
                return bad(format!(
                    "faults.fail_node: slave {} out of range (cluster.slaves = {})",
                    d.slave, self.cluster.slaves
                ));
            }
            if d.at_heartbeat == 0 {
                return bad("faults.fail_node: heartbeat must be >= 1".into());
            }
        }
        if self.knn.t == 0 {
            return bad("knn.t must be >= 1".into());
        }
        if self.knn.leaf_size == 0 {
            return bad("knn.leaf_size must be >= 1".into());
        }
        if self.algo.k < 2 {
            return bad(format!("algo.k must be >= 2, got {}", self.algo.k));
        }
        if let SigmaSpec::Fixed(s) = self.algo.sigma {
            if s <= 0.0 {
                return bad(format!("algo.sigma must be > 0, got {s}"));
            }
        }
        if self.algo.lanczos_steps < self.algo.k {
            return bad(format!(
                "algo.lanczos_steps ({}) must be >= algo.k ({})",
                self.algo.lanczos_steps, self.algo.k
            ));
        }
        if self.algo.kmeans_iters == 0 {
            return bad("algo.kmeans_iters must be >= 1".into());
        }
        if self.eigen.block_size == 0 {
            return bad("eigen.block_size must be >= 1".into());
        }
        if self.eigen.filter_degree == 0 {
            return bad("eigen.filter_degree must be >= 1".into());
        }
        if self.eigen.max_outer == 0 {
            return bad("eigen.max_outer must be >= 1".into());
        }
        if self.eigen.residual_tol <= 0.0 {
            return bad(format!(
                "eigen.residual_tol must be > 0, got {}",
                self.eigen.residual_tol
            ));
        }
        if self.eigen.bound_steps == 0 {
            return bad("eigen.bound_steps must be >= 1".into());
        }
        if self.serving.batch_points == 0 {
            return bad("serving.batch_points must be >= 1".into());
        }
        Ok(())
    }
}

/// Parse `[section]` / `key = value` / `#`-comment lines into dotted pairs.
fn parse_kv(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(Error::Config(format!(
                "line {}: expected key = value, got {line:?}",
                lineno + 1
            )));
        };
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let value = v.trim().trim_matches('"').to_string();
        out.push((key, value));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn parse_full_file() {
        let text = r#"
# experiment config
[cluster]
slaves = 8
slots_per_slave = 2
replication = 3
net_bw = 1.1e8

[algo]
k = 5
sigma = 0.75
lanczos_steps = 40
"#;
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.cluster.slaves, 8);
        assert_eq!(cfg.cluster.replication, 3);
        assert!((cfg.cluster.network.net_bw - 1.1e8).abs() < 1.0);
        assert_eq!(cfg.algo.k, 5);
        assert!((cfg.algo.sigma.fixed().unwrap() - 0.75).abs() < 1e-12);
        // Untouched keys keep defaults.
        assert_eq!(cfg.algo.kmeans_iters, 20);
    }

    #[test]
    fn rejects_unknown_key_and_bad_values() {
        assert!(Config::parse("[cluster]\nbogus = 1\n").is_err());
        assert!(Config::parse("[algo]\nk = banana\n").is_err());
        assert!(Config::parse("[algo]\nk 5\n").is_err());
    }

    #[test]
    fn validation_catches_inconsistency() {
        assert!(Config::parse("[algo]\nk = 1\n").is_err(), "k < 2");
        assert!(
            Config::parse("[algo]\nk = 10\nlanczos_steps = 5\n").is_err(),
            "lanczos < k"
        );
        assert!(Config::parse("[cluster]\nslaves = 0\n").is_err());
        assert!(Config::parse("[algo]\nsigma = -1\n").is_err());
    }

    #[test]
    fn sigma_auto_parses_and_numeric_stays_validated() {
        let cfg = Config::parse("[algo]\nsigma = \"auto\"\n").unwrap();
        assert_eq!(cfg.algo.sigma, SigmaSpec::Auto);
        assert!(cfg.algo.sigma.is_auto());
        assert_eq!(cfg.algo.sigma.fixed(), None);
        // Explicit numeric sigma is unchanged by the auto mode existing.
        let cfg = Config::parse("[algo]\nsigma = 2.25\n").unwrap();
        assert_eq!(cfg.algo.sigma, SigmaSpec::Fixed(2.25));
        assert_eq!(cfg.algo.sigma.fixed(), Some(2.25));
        assert_eq!(SigmaSpec::from(1.5), SigmaSpec::Fixed(1.5));
        // Zero/negative/garbage stay rejected.
        assert!(Config::parse("[algo]\nsigma = 0\n").is_err());
        assert!(Config::parse("[algo]\nsigma = -2\n").is_err());
        assert!(Config::parse("[algo]\nsigma = banana\n").is_err());
    }

    #[test]
    fn serving_keys_parse_and_validate() {
        let text = "[serving]\nlandmarks = 128\nbatch_points = 64\nrefresh = minibatch\n";
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.serving.landmarks, 128);
        assert_eq!(cfg.serving.batch_points, 64);
        assert_eq!(cfg.serving.refresh, RefreshMode::Minibatch);
        // Untouched keys keep inert defaults (all training points kept as
        // landmarks, refresh off).
        let plain = Config::default();
        assert_eq!(plain.serving, ServingConfig::default());
        assert_eq!(plain.serving.landmarks, 0);
        assert_eq!(plain.serving.refresh, RefreshMode::Off);
        assert!(plain.serving.batch_points >= 1);

        assert!(Config::parse("[serving]\nrefresh = banana\n").is_err());
        assert!(Config::parse("[serving]\nbatch_points = 0\n").is_err());
        assert!(Config::parse("[serving]\nbogus = 1\n").is_err());
    }

    #[test]
    fn scheduler_keys_parse_and_validate() {
        let text = "[cluster]\nracks = 2\nscheduler = fifo\nheartbeat_s = 1.5\nspeculation = false\n";
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.cluster.racks, 2);
        assert_eq!(cfg.cluster.scheduler, Policy::Fifo);
        assert!((cfg.cluster.heartbeat_s - 1.5).abs() < 1e-12);
        assert!(!cfg.cluster.speculation.enabled);

        let cfg = Config::parse("[cluster]\nlocality_delay = 5\n").unwrap();
        assert_eq!(
            cfg.cluster.scheduler,
            Policy::LocalityAware { locality_delay: 5 }
        );
        // Key order never matters: fifo always wins over a delay knob, a
        // delay set before `scheduler = locality` survives the switch, and
        // a delay set while fifo is active is remembered.
        let fifo_first = Config::parse("[cluster]\nscheduler = fifo\nlocality_delay = 5\n").unwrap();
        assert_eq!(fifo_first.cluster.scheduler, Policy::Fifo);
        let delay_first =
            Config::parse("[cluster]\nlocality_delay = 5\nscheduler = locality\n").unwrap();
        assert_eq!(
            delay_first.cluster.scheduler,
            Policy::LocalityAware { locality_delay: 5 }
        );
        let via_fifo = Config::parse(
            "[cluster]\nscheduler = fifo\nlocality_delay = 5\nscheduler = locality\n",
        )
        .unwrap();
        assert_eq!(
            via_fifo.cluster.scheduler,
            Policy::LocalityAware { locality_delay: 5 }
        );

        assert!(Config::parse("[cluster]\nscheduler = bogus\n").is_err());
        assert!(Config::parse("[cluster]\nracks = 0\n").is_err());
        assert!(Config::parse("[cluster]\nheartbeat_s = 0\n").is_err());
        assert!(Config::parse("[cluster]\nspeculative_slowdown = 0.5\n").is_err());
    }

    #[test]
    fn shuffle_keys_parse_and_validate() {
        let text =
            "[shuffle]\nsort_buffer_kb = 256\nmerge_factor = 4\nfetch_parallelism = 8\n";
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.shuffle.sort_buffer_kb, 256);
        assert_eq!(cfg.shuffle.merge_factor, 4);
        assert_eq!(cfg.shuffle.fetch_parallelism, 8);
        // Untouched shuffle keys keep Hadoop-flavoured defaults.
        let plain = Config::default();
        assert_eq!(plain.shuffle.merge_factor, 10);
        assert_eq!(plain.shuffle.fetch_parallelism, 5);

        assert!(Config::parse("[shuffle]\nsort_buffer_kb = 0\n").is_err());
        assert!(Config::parse("[shuffle]\nmerge_factor = 1\n").is_err());
        assert!(Config::parse("[shuffle]\nfetch_parallelism = 0\n").is_err());
        assert!(Config::parse("[shuffle]\nbogus = 1\n").is_err());
    }

    #[test]
    fn fault_keys_parse_and_validate() {
        let text = "[cluster]\nslaves = 4\n\n[faults]\nseed = 9\ntask_fail_prob = 0.05\n\
                    max_attempts = 6\nblacklist_after = 2\nfail_node = \"1@40, 3@90\"\n";
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.faults.seed, 9);
        assert!((cfg.faults.task_fail_prob - 0.05).abs() < 1e-12);
        assert_eq!(cfg.faults.max_attempts, 6);
        assert_eq!(cfg.faults.blacklist_after, 2);
        assert_eq!(
            cfg.faults.node_deaths,
            vec![
                NodeDeath { slave: 1, at_heartbeat: 40 },
                NodeDeath { slave: 3, at_heartbeat: 90 }
            ]
        );
        // Defaults are inert.
        let plain = Config::default();
        assert!(!plain.faults.is_active());
        assert_eq!(plain.faults.max_attempts, 4);
        // An empty fail_node clears the schedule.
        let mut cleared = cfg.clone();
        cleared.set("faults.fail_node", "").unwrap();
        assert!(cleared.faults.node_deaths.is_empty());

        assert!(Config::parse("[faults]\ntask_fail_prob = 1.5\n").is_err());
        assert!(Config::parse("[faults]\nmax_attempts = 0\n").is_err());
        assert!(Config::parse("[faults]\nblacklist_after = 0\n").is_err());
        assert!(Config::parse("[faults]\nfail_node = banana\n").is_err());
        assert!(
            Config::parse("[cluster]\nslaves = 2\n[faults]\nfail_node = 5@3\n").is_err(),
            "death of a slave the cluster does not have"
        );
        assert!(Config::parse("[faults]\nfail_node = 0@0\n").is_err());
    }

    #[test]
    fn knn_keys_parse_and_validate() {
        let text = "[algo]\ngraph = tnn\n\n[knn]\nt = 7\nleaf_size = 4\nindex = brute\n";
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.algo.graph, GraphMode::Tnn);
        assert_eq!(cfg.knn.t, 7);
        assert_eq!(cfg.knn.leaf_size, 4);
        assert_eq!(cfg.knn.index, IndexKind::Brute);
        // Untouched keys keep the defaults, and the defaults stay epsilon.
        let plain = Config::default();
        assert_eq!(plain.algo.graph, GraphMode::Epsilon);
        assert_eq!(plain.knn, KnnConfig::default());
        assert_eq!(plain.knn.t, 10);
        assert_eq!(plain.knn.index, IndexKind::KdTree);

        assert!(Config::parse("[algo]\ngraph = banana\n").is_err());
        assert!(Config::parse("[knn]\nindex = banana\n").is_err());
        assert!(Config::parse("[knn]\nt = 0\n").is_err());
        assert!(Config::parse("[knn]\nleaf_size = 0\n").is_err());
        assert!(Config::parse("[knn]\nbogus = 1\n").is_err());
    }

    #[test]
    fn eigen_keys_parse_and_validate() {
        let text = "[eigen]\nsolver = chebdav\nblock_size = 6\nfilter_degree = 6\n\
                    max_outer = 4\nresidual_tol = 1e-5\nbound_steps = 3\n";
        let cfg = Config::parse(text).unwrap();
        assert_eq!(cfg.eigen.solver, EigenSolverKind::ChebDav);
        assert_eq!(cfg.eigen.block_size, 6);
        assert_eq!(cfg.eigen.filter_degree, 6);
        assert_eq!(cfg.eigen.max_outer, 4);
        assert!((cfg.eigen.residual_tol - 1e-5).abs() < 1e-18);
        assert_eq!(cfg.eigen.bound_steps, 3);
        // The backend defaults to lanczos so existing configs are inert.
        let plain = Config::default();
        assert_eq!(plain.eigen, EigenConfig::default());
        assert_eq!(plain.eigen.solver, EigenSolverKind::Lanczos);
        // The paper-facing alias hits the same field.
        let mut aliased = Config::default();
        aliased.set("algo.eigensolver", "chebdav").unwrap();
        assert_eq!(aliased.eigen.solver, EigenSolverKind::ChebDav);

        assert!(Config::parse("[eigen]\nsolver = banana\n").is_err());
        assert!(Config::parse("[eigen]\nblock_size = 0\n").is_err());
        assert!(Config::parse("[eigen]\nfilter_degree = 0\n").is_err());
        assert!(Config::parse("[eigen]\nmax_outer = 0\n").is_err());
        assert!(Config::parse("[eigen]\nresidual_tol = 0\n").is_err());
        assert!(Config::parse("[eigen]\nbound_steps = 0\n").is_err());
        assert!(Config::parse("[eigen]\nbogus = 1\n").is_err());
    }

    #[test]
    fn cli_style_set() {
        let mut cfg = Config::default();
        cfg.set("cluster.slaves", "10").unwrap();
        cfg.set("algo.seed", "7").unwrap();
        assert_eq!(cfg.cluster.slaves, 10);
        assert_eq!(cfg.algo.seed, 7);
        assert!(cfg.set("nope", "1").is_err());
    }
}
