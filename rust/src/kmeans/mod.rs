//! K-means: Lloyd baseline + k-means++ init (paper Alg. 4.1 step 6).
//!
//! The single-machine implementation here is both the baseline comparator
//! and the oracle the distributed phase-3 job (coordinator/kmeans_job.rs) is
//! validated against.

use crate::linalg::vector::sq_dist;
use crate::util::Xoshiro256;

/// Result of a k-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// Cluster index per point.
    pub labels: Vec<usize>,
    /// Final centers, k × d.
    pub centers: Vec<Vec<f64>>,
    /// Iterations executed.
    pub iterations: usize,
    /// Sum of squared distances to assigned centers.
    pub inertia: f64,
    /// Whether the tolerance was hit before the iteration cap.
    pub converged: bool,
}

/// Initialization strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    /// Uniform random distinct points (the paper's implicit choice).
    Random,
    /// k-means++ (D² sampling) — better spread, fewer iterations.
    PlusPlus,
}

/// Pick initial centers.
pub fn init_centers(
    points: &[Vec<f64>],
    k: usize,
    init: Init,
    seed: u64,
) -> Vec<Vec<f64>> {
    assert!(k >= 1 && k <= points.len(), "k={k} vs n={}", points.len());
    let mut rng = Xoshiro256::new(seed);
    match init {
        Init::Random => rng
            .sample_indices(points.len(), k)
            .into_iter()
            .map(|i| points[i].clone())
            .collect(),
        Init::PlusPlus => {
            let mut centers = vec![points[rng.next_index(points.len())].clone()];
            let mut d2: Vec<f64> = points
                .iter()
                .map(|p| sq_dist(p, &centers[0]))
                .collect();
            while centers.len() < k {
                let total: f64 = d2.iter().sum();
                let next = if total <= 0.0 {
                    rng.next_index(points.len())
                } else {
                    let mut target = rng.next_f64() * total;
                    let mut pick = points.len() - 1;
                    for (i, &w) in d2.iter().enumerate() {
                        if target < w {
                            pick = i;
                            break;
                        }
                        target -= w;
                    }
                    pick
                };
                centers.push(points[next].clone());
                for (i, p) in points.iter().enumerate() {
                    let nd = sq_dist(p, centers.last().unwrap());
                    if nd < d2[i] {
                        d2[i] = nd;
                    }
                }
            }
            centers
        }
    }
}

/// Assign each point to its nearest center (ties to the lowest index —
/// the behavior of the original `min_by` scan, which keeps the first
/// minimum). Routed through the blocked assignment tile
/// ([`crate::linalg::kernels::assign_point`]) with center norms hoisted
/// once per call; bit-identical selection by the kernel-layer contract.
pub fn assign(points: &[Vec<f64>], centers: &[Vec<f64>]) -> Vec<usize> {
    assert!(!centers.is_empty(), "assign needs at least one center");
    let k = centers.len();
    let d = centers[0].len();
    let flat: Vec<f64> = centers.iter().flatten().copied().collect();
    let norms = crate::linalg::kernels::center_norms(&flat, k, d);
    points
        .iter()
        .map(|p| crate::linalg::kernels::assign_point(p, &flat, &norms, k, d) as usize)
        .collect()
}

/// Lloyd's algorithm.
pub fn lloyd(
    points: &[Vec<f64>],
    k: usize,
    max_iters: usize,
    tol: f64,
    init: Init,
    seed: u64,
) -> KmeansResult {
    let n = points.len();
    let d = points[0].len();
    let mut centers = init_centers(points, k, init, seed);
    let mut labels = vec![0usize; n];
    let mut iterations = 0;
    let mut converged = false;

    for _iter in 0..max_iters {
        iterations += 1;
        labels = assign(points, &centers);
        // Update step.
        let mut sums = vec![vec![0.0; d]; k];
        let mut counts = vec![0usize; k];
        for (p, &l) in points.iter().zip(&labels) {
            counts[l] += 1;
            for t in 0..d {
                sums[l][t] += p[t];
            }
        }
        // Compare squared movement against the squared tolerance: sqrt is
        // monotone, so `max(dist) < tol` ⟺ `max(dist²) < tol²` — the same
        // convergence decision without k square roots per iteration.
        let mut movement_sq: f64 = 0.0;
        for c in 0..k {
            if counts[c] == 0 {
                continue; // empty cluster keeps its center (paper's behaviour)
            }
            let new_center: Vec<f64> =
                sums[c].iter().map(|s| s / counts[c] as f64).collect();
            movement_sq = movement_sq.max(sq_dist(&new_center, &centers[c]));
            centers[c] = new_center;
        }
        if movement_sq < tol * tol {
            converged = true;
            break;
        }
    }
    labels = assign(points, &centers);
    let inertia = points
        .iter()
        .zip(&labels)
        .map(|(p, &l)| sq_dist(p, &centers[l]))
        .sum();
    KmeansResult { labels, centers, iterations, inertia, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::eval::nmi;

    #[test]
    fn recovers_separated_blobs() {
        let ps = gaussian_blobs(300, 3, 2, 0.3, 15.0, 5);
        let r = lloyd(&ps.points, 3, 50, 1e-8, Init::PlusPlus, 7);
        assert!(r.converged);
        assert!(nmi(&ps.labels, &r.labels) > 0.98, "nmi too low");
        assert_eq!(r.centers.len(), 3);
    }

    #[test]
    fn inertia_decreases_with_k() {
        let ps = gaussian_blobs(200, 4, 2, 0.5, 10.0, 2);
        let r2 = lloyd(&ps.points, 2, 50, 1e-8, Init::PlusPlus, 3);
        let r4 = lloyd(&ps.points, 4, 50, 1e-8, Init::PlusPlus, 3);
        assert!(r4.inertia < r2.inertia);
    }

    #[test]
    fn one_cluster_center_is_mean() {
        let pts = vec![vec![0.0, 0.0], vec![2.0, 0.0], vec![1.0, 3.0]];
        let r = lloyd(&pts, 1, 10, 1e-12, Init::Random, 1);
        assert!((r.centers[0][0] - 1.0).abs() < 1e-9);
        assert!((r.centers[0][1] - 1.0).abs() < 1e-9);
        assert_eq!(r.labels, vec![0, 0, 0]);
    }

    #[test]
    fn deterministic_by_seed() {
        let ps = gaussian_blobs(100, 3, 2, 0.4, 8.0, 9);
        let a = lloyd(&ps.points, 3, 30, 1e-8, Init::PlusPlus, 11);
        let b = lloyd(&ps.points, 3, 30, 1e-8, Init::PlusPlus, 11);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn plusplus_spreads_initial_centers() {
        let ps = gaussian_blobs(200, 4, 2, 0.2, 20.0, 13);
        let centers = init_centers(&ps.points, 4, Init::PlusPlus, 17);
        // All pairwise distances should be large (one per blob, typically).
        let mut min_d2 = f64::INFINITY;
        for i in 0..4 {
            for j in (i + 1)..4 {
                min_d2 = min_d2.min(sq_dist(&centers[i], &centers[j]));
            }
        }
        assert!(min_d2 > 4.0, "++ centers clumped: {min_d2}");
    }

    #[test]
    fn kmeans_fails_on_rings_motivating_spectral() {
        // The paper's §3.1 motivation: k-means cannot separate concentric
        // rings; spectral clustering can (tested in spectral/).
        let ps = crate::data::two_rings(300, 1.0, 6.0, 0.05, 3);
        let r = lloyd(&ps.points, 2, 100, 1e-9, Init::PlusPlus, 5);
        assert!(
            nmi(&ps.labels, &r.labels) < 0.3,
            "k-means should NOT solve rings: nmi={}",
            nmi(&ps.labels, &r.labels)
        );
    }

    #[test]
    #[should_panic]
    fn k_larger_than_n_panics() {
        init_centers(&[vec![0.0]], 2, Init::Random, 1);
    }
}
