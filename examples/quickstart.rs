//! Quickstart: cluster Gaussian blobs with the full parallel pipeline.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::eval::{ari, nmi};
use psch::runtime::KernelRuntime;
use psch::util::fmt::hms;

fn main() -> psch::Result<()> {
    // 1. Data: 4 Gaussian blobs in 8 dimensions.
    let dataset = gaussian_blobs(1_000, 4, 8, 0.4, 8.0, 42);

    // 2. Config: 4 slaves, defaults otherwise (see rust/src/config/).
    let mut config = Config::default();
    config.cluster.slaves = 4;
    config.algo.k = 4;
    config.algo.sigma = 1.5;

    // 3. Runtime: AOT XLA artifacts when present, native fallback otherwise.
    let runtime = Arc::new(KernelRuntime::auto(&psch::runtime::artifacts_dir()));
    println!("kernel backend: {:?}", runtime.backend());

    // 4. Run the three-phase pipeline (Alg. 4.2 / 4.3 / §4.3.3).
    let driver = Driver::new(config, runtime);
    let result = driver.run(&PipelineInput::Points { points: dataset.points.clone() })?;

    // 5. Report.
    for phase in &result.phases {
        println!(
            "  {:<14} virtual {:>8}  ({} MR jobs)",
            phase.name,
            hms(std::time::Duration::from_secs_f64(phase.virtual_s)),
            phase.jobs
        );
    }
    println!(
        "labels: NMI={:.4} ARI={:.4} vs ground truth",
        nmi(&dataset.labels, &result.labels),
        ari(&dataset.labels, &result.labels)
    );
    assert!(nmi(&dataset.labels, &result.labels) > 0.9, "clustering failed");
    println!("quickstart OK");
    Ok(())
}
