//! Table 5-1 / Fig. 5 in miniature: per-phase virtual time of the parallel
//! pipeline as the slave count sweeps 1..10.
//!
//! The full paper-scale regeneration is `cargo bench --bench table1`; this
//! example runs a scaled-down dataset so it finishes fast and prints the
//! same table + trend chart.

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::gaussian_blobs;
use psch::metrics::speedup::SpeedupCurve;
use psch::metrics::table::AsciiTable;
use psch::runtime::KernelRuntime;
use psch::util::fmt::hms;

fn main() -> psch::Result<()> {
    let n = 2_000;
    let dataset = gaussian_blobs(n, 4, 8, 0.4, 8.0, 42);
    let input = PipelineInput::Points { points: dataset.points.clone() };
    let runtime = Arc::new(KernelRuntime::auto(&psch::runtime::artifacts_dir()));
    println!("kernel backend: {:?}; n={n}", runtime.backend());

    let mut table = AsciiTable::new(&[
        "Slave Number",
        "Parallel similarity",
        "Parallel k eigenvectors",
        "Parallel K-means",
        "Total Time",
    ]);
    let mut curve = SpeedupCurve::default();
    for m in [1usize, 2, 4, 6, 8, 10] {
        let mut config = Config::default();
        config.cluster.slaves = m;
        config.algo.k = 4;
        config.algo.sigma = 1.5;
        config.algo.lanczos_steps = 40;
        // Lighter coordination constants than benches/table1.rs: at this
        // reduced n the per-iteration jobs are small, and the paper-scale
        // constants would (truthfully) show "too small to parallelize".
        config.cluster.network.job_setup_s = 1.0;
        config.cluster.network.task_dispatch_s = 0.5;
        config.cluster.network.disk_bw = 5e6;
        config.cluster.network.net_bw = 40e6;
        config.cluster.network.coord_per_machine_s = 0.3;
        config.cluster.network.shuffle_latency_s = 0.2;
        let driver = Driver::new(config, runtime.clone());
        let r = driver.run(&input)?;
        let d = |s: f64| hms(std::time::Duration::from_secs_f64(s));
        table.row(&[
            m.to_string(),
            d(r.phases[0].virtual_s),
            d(r.phases[1].virtual_s),
            d(r.phases[2].virtual_s),
            d(r.total_virtual_s),
        ]);
        curve.push(m, r.total_virtual_s);
    }
    println!("{}", table.render());
    println!("speedup vs 1 slave:");
    for (m, s) in curve.speedups() {
        println!("  m={m:>2}: {s:.2}x");
    }
    println!("\ntrend (Fig. 5):\n{}", curve.ascii_plot(48, 12));
    // At this reduced n the wave-count discreteness makes individual steps
    // wiggle; the headline claims still hold: parallelism pays up to 8
    // slaves, and the 8->10 step adds little (the paper's crossover).
    let s8 = curve
        .speedups()
        .iter()
        .find(|&&(m, _)| m == 8)
        .map(|&(_, s)| s)
        .unwrap();
    assert!(s8 > 1.3, "8 slaves should clearly beat 1: {s8:.2}x");
    println!("scaling_study OK (speedup@8 = {s8:.2}x)");
    Ok(())
}
