//! End-to-end driver (DESIGN.md §6): the paper's Chapter-5 experiment.
//!
//! Generates the paper-scale planted graph — 10,029 vertices, 21,054 edges —
//! writes it in the Fig. 4 topology text format, stores it in mini-HDFS,
//! parses it back, runs the full three-phase parallel pipeline on the
//! simulated cluster (XLA kernels on the hot path), and reports per-phase
//! virtual time plus clustering quality against the planted truth.
//!
//! Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;

use psch::config::Config;
use psch::coordinator::{Driver, PipelineInput};
use psch::data::{paper_scale_graph, Topology};
use psch::eval::{ari, nmi, purity};
use psch::runtime::KernelRuntime;
use psch::util::fmt::hms;

fn main() -> psch::Result<()> {
    // ---- 1. Generate + round-trip the paper's dataset through Fig. 4 text.
    let topo = paper_scale_graph(4, 1);
    println!(
        "dataset: {} vertices, {} edges (paper: 10029 / 21054)",
        topo.num_vertices(),
        topo.num_edges()
    );
    let text = topo.to_text();

    // ---- 2. Store the file in mini-HDFS and read it back (paper §2.1).
    let mut config = Config::default();
    config.cluster.slaves = 8;
    config.algo.k = 4;
    config.algo.lanczos_steps = 60;
    let runtime = Arc::new(KernelRuntime::auto(&psch::runtime::artifacts_dir()));
    println!("kernel backend: {:?}", runtime.backend());
    let driver = Driver::new(config, runtime);
    let services = driver.services();
    services.dfs.write_file("/input/topology.txt", text.as_bytes())?;
    let stored = services.dfs.read_file("/input/topology.txt")?;
    let parsed = Topology::parse(std::str::from_utf8(&stored).unwrap())?;
    assert_eq!(parsed.num_vertices(), topo.num_vertices());
    assert_eq!(parsed.num_edges(), topo.num_edges());
    println!(
        "stored {} bytes in mini-HDFS ({} replicas)",
        stored.len(),
        services.dfs.replication()
    );

    // ---- 3. Run the three-phase pipeline on the graph.
    let truth = parsed.labels();
    let t0 = std::time::Instant::now();
    let result = driver.run_on(&services, &PipelineInput::Graph { topology: parsed })?;
    let wall = t0.elapsed();

    // ---- 4. Report (EXPERIMENTS.md records this).
    println!("\nphase results (m=8 slaves):");
    for phase in &result.phases {
        println!(
            "  {:<14} virtual {:>8}  wall {:>7.2}s  {} jobs  shuffle {}",
            phase.name,
            hms(std::time::Duration::from_secs_f64(phase.virtual_s)),
            phase.wall_s,
            phase.jobs,
            psch::util::fmt::human_bytes(phase.shuffle_bytes),
        );
    }
    println!(
        "  {:<14} virtual {:>8}  wall {:>7.2}s",
        "TOTAL",
        hms(std::time::Duration::from_secs_f64(result.total_virtual_s)),
        wall.as_secs_f64()
    );
    println!(
        "\nquality vs planted communities: NMI={:.4} ARI={:.4} purity={:.4}",
        nmi(&truth, &result.labels),
        ari(&truth, &result.labels),
        purity(&truth, &result.labels),
    );
    println!(
        "eigenvalues (k smallest of L_sym): {:?}",
        result
            .eigenvalues
            .iter()
            .map(|v| (v * 1e4).round() / 1e4)
            .collect::<Vec<_>>()
    );
    assert!(
        nmi(&truth, &result.labels) > 0.5,
        "community recovery too weak"
    );
    println!("graph_clustering OK");
    Ok(())
}
