//! The paper's §3.1 motivation: spectral clustering handles arbitrary
//! cluster shapes (rings, moons) where k-means fails.
//!
//! Runs both algorithms on two rings and two moons and prints the NMI
//! side by side.

use psch::data::{two_moons, two_rings};
use psch::eval::nmi;
use psch::kmeans::{lloyd, Init};
use psch::spectral::{spectral_cluster_points, Eigensolver, SpectralParams};

fn main() -> psch::Result<()> {
    let cases = [
        ("two_rings", two_rings(500, 1.0, 6.0, 0.08, 7), 0.4),
        ("two_moons", two_moons(500, 0.06, 7), 0.25),
    ];
    println!("{:<12} {:>14} {:>10}", "dataset", "spectral NMI", "kmeans NMI");
    for (name, ps, sigma) in cases {
        let params = SpectralParams {
            k: 2,
            sigma,
            lanczos_steps: 100,
            ..Default::default()
        };
        let spectral =
            spectral_cluster_points(&ps.points, &params, Eigensolver::Lanczos)?;
        let kmeans = lloyd(&ps.points, 2, 100, 1e-9, Init::PlusPlus, 5);
        let s_nmi = nmi(&ps.labels, &spectral.labels);
        let k_nmi = nmi(&ps.labels, &kmeans.labels);
        println!("{name:<12} {s_nmi:>14.4} {k_nmi:>10.4}");
        assert!(
            s_nmi > k_nmi,
            "{name}: spectral ({s_nmi}) should beat k-means ({k_nmi})"
        );
    }
    println!("shapes_demo OK: spectral wins on non-convex shapes");
    Ok(())
}
