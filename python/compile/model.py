"""Layer-2 JAX model: the per-MR-task compute graphs of the paper's pipeline.

Each entry point here is what one MapReduce task executes on its tile of data;
the Rust coordinator (Layer 3) calls the AOT-compiled HLO of these functions
via PJRT. They compose the Layer-1 Pallas kernels with the surrounding jnp
glue so everything lowers into ONE fused HLO module per entry point.

Build-time only: nothing in this package is imported at runtime.
"""

import jax
import jax.numpy as jnp

from compile.kernels.kmeans import kmeans_step as _kmeans_kernel
from compile.kernels.matvec import matvec_block as _matvec_kernel
from compile.kernels.normalize import normalize_rows as _normalize_kernel
from compile.kernels.rbf import rbf_block as _rbf_kernel


def similarity_block(x, y, gamma):
    """Paper Alg. 4.2 inner compute: one (P, Q) tile of S = exp(-gamma d^2)."""
    return _rbf_kernel(x, y, gamma)


def similarity_degree_block(x, y, gamma):
    """Fused phase-1 tile: similarity tile AND its row-sum contribution.

    The degree d_i = sum_j S_ij (Alg. 4.1 step 2) is accumulated for free
    while the tile is resident, saving a second pass over S.
    """
    s = _rbf_kernel(x, y, gamma)
    return s, jnp.sum(s, axis=1)


def matvec_block(a, v):
    """Paper Alg. 4.3 hot spot: y_block = L_rows . v for one row block."""
    return _matvec_kernel(a, v)


def laplacian_block(s, dinv_r, dinv_c, is_diag):
    """L_sym tile from an S tile: is_diag * I - diag(dinv_r) S diag(dinv_c).

    dinv_* carry d^{-1/2} slices; is_diag is 1.0 iff the tile lies on the
    global diagonal. Pure jnp (elementwise — no kernel needed, XLA fuses it).
    """
    eye = jnp.eye(s.shape[0], s.shape[1], dtype=s.dtype)
    return is_diag * eye - dinv_r[:, None] * s * dinv_c[None, :]


def kmeans_step(points, centers, mask):
    """Paper §4.3.3 map+combiner: (assign, per-center sums, counts)."""
    return _kmeans_kernel(points, centers, mask)


def normalize_rows(z):
    """Paper Alg. 4.1 step 5: row-normalize the eigenvector matrix Z -> Y."""
    return _normalize_kernel(z)


def degree_rowsum(s):
    """Degrees d_i = sum_j S_ij over one row block (Alg. 4.1 step 2)."""
    return jnp.sum(s, axis=1)


# ---------------------------------------------------------------------------
# AOT manifest: name -> (callable, example input ShapeDtypeStructs).
# Shapes here are the fixed tile geometry the Rust runtime pads to
# (rust/src/runtime/executor.rs must agree — see artifacts/manifest.txt).
# ---------------------------------------------------------------------------

f32 = jnp.float32


def _s(shape, dtype=f32):
    return jax.ShapeDtypeStruct(shape, dtype)


ENTRY_POINTS = {
    "rbf_block": (
        similarity_block,
        (_s((128, 16)), _s((128, 16)), _s(())),
    ),
    "similarity_degree_block": (
        similarity_degree_block,
        (_s((128, 16)), _s((128, 16)), _s(())),
    ),
    "matvec_block": (
        matvec_block,
        (_s((256, 256)), _s((256,))),
    ),
    "laplacian_block": (
        laplacian_block,
        (_s((256, 256)), _s((256,)), _s((256,)), _s(())),
    ),
    "kmeans_step": (
        kmeans_step,
        (_s((256, 16)), _s((16, 16)), _s((256,))),
    ),
    "normalize_rows": (
        normalize_rows,
        (_s((128, 16)),),
    ),
    "degree_rowsum": (
        degree_rowsum,
        (_s((128, 128)),),
    ),
}
