"""AOT compile path: lower every Layer-2 entry point to HLO text artifacts.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. Lowering goes jitted-fn -> stablehlo -> XlaComputation
(return_tuple=True, so the Rust side always unwraps a tuple) -> as_hlo_text.

Also writes ``artifacts/manifest.txt``: one line per artifact with its input
shapes/dtypes and output arity, parsed by rust/src/runtime/artifact.rs to
validate tile geometry at load time.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import ENTRY_POINTS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(spec) -> str:
    shape = "x".join(str(d) for d in spec.shape) if spec.shape else "scalar"
    return f"{spec.dtype}[{shape}]"


def _out_arity(fn, specs) -> int:
    out = jax.eval_shape(fn, *specs)
    return len(out) if isinstance(out, (tuple, list)) else 1


def compile_all(out_dir: str, force: bool = False) -> list:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    written = []
    for name, (fn, specs) in sorted(ENTRY_POINTS.items()):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        ins = ";".join(_spec_str(s) for s in specs)
        arity = _out_arity(fn, specs)
        manifest_lines.append(f"{name}|{ins}|{arity}")
        if os.path.exists(path) and not force:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        written.append(name)
        print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args()
    written = compile_all(args.out_dir, force=args.force)
    print(f"AOT: {len(written)} artifact(s) written to {args.out_dir}")


if __name__ == "__main__":
    main()
