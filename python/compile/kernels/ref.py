"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness contracts: each Pallas kernel in this package must
match its oracle to float32 tolerance for all shapes/values the test suite
sweeps (pytest + hypothesis). The Rust native fallbacks in
``rust/src/runtime/native.rs`` mirror the same math and are parity-tested
against the XLA-compiled artifacts on the Rust side.
"""

import jax.numpy as jnp


def rbf_block_ref(x, y, gamma):
    """RBF similarity tile: S[i, j] = exp(-gamma * ||x_i - y_j||^2).

    ``gamma = 1 / (2 sigma^2)`` per the paper's Eq. in §3.2.3.
    Shapes: x (P, D), y (Q, D), gamma scalar -> (P, Q).
    """
    d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    return jnp.exp(-gamma * d2)


def matvec_block_ref(a, v):
    """Dense row-block mat-vec: y = A v. Shapes: a (R, N), v (N,) -> (R,)."""
    return a @ v


def kmeans_step_ref(points, centers, mask):
    """One k-means assignment + partial-sum step.

    points (P, D), centers (K, D), mask (P,) in {0, 1} marking valid
    (non-padding) points. Returns:
      assign (P,) int32   — nearest-center index (computed for ALL rows,
                             padding included; callers must apply the mask),
      sums   (K, D) f32   — per-center coordinate sums over valid points,
      counts (K,)  f32    — per-center valid point counts.
    """
    d2 = jnp.sum((points[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    onehot = (assign[:, None] == jnp.arange(centers.shape[0])[None, :]).astype(
        jnp.float32
    ) * mask[:, None]
    sums = onehot.T @ points
    counts = jnp.sum(onehot, axis=0)
    return assign, sums, counts


def normalize_rows_ref(z):
    """Row-wise L2 normalization (paper's step 5, Z -> Y); zero rows stay zero."""
    norm = jnp.sqrt(jnp.sum(z * z, axis=1, keepdims=True))
    return z / jnp.where(norm == 0.0, 1.0, norm)


def laplacian_block_ref(s, dinv_r, dinv_c, is_diag):
    """Normalized-Laplacian tile: L = is_diag * I - diag(dinv_r) S diag(dinv_c).

    ``dinv_*`` are the relevant slices of d^{-1/2}; ``is_diag`` is 1.0 when the
    tile sits on the global diagonal (row range == col range), else 0.0.
    Shapes: s (R, C), dinv_r (R,), dinv_c (C,), is_diag scalar -> (R, C).
    """
    eye = jnp.eye(s.shape[0], s.shape[1], dtype=s.dtype)
    return is_diag * eye - dinv_r[:, None] * s * dinv_c[None, :]


def degree_rowsum_ref(s):
    """Degree of each row: d_i = sum_j S[i, j]. Shape (R, C) -> (R,)."""
    return jnp.sum(s, axis=1)
