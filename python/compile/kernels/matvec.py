"""Layer-1 Pallas kernel: row-blocked dense mat-vec (Lanczos ``L v`` hot spot).

The paper's phase 2 moves the vector v to the row-partitioned matrix in HBase
("mobile computing"); each MR map task computes y_block = A_rows . v. This
kernel is that per-task compute: the row block is tiled BLK rows at a time,
each grid step contracting a (BLK, N) strip against the full resident v —
a (BLK, N) x (N, 1) MXU contraction. VMEM per step at BLK=128, N=256:
128*256 + 256 + 128 floats ~= 130 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Geometry baked into the AOT artifact.
N = 256  # columns per block (and v length)
ROWS = 256  # rows per block
BLK = 128  # rows per grid step


def _mv_kernel(a_ref, v_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], v_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("blk",))
def matvec_block(a, v, *, blk=BLK):
    """y = A v for one row block. a (R, C), v (C,); R must divide by ``blk``."""
    r, c = a.shape
    assert v.shape == (c,), (a.shape, v.shape)
    assert r % blk == 0, (r, blk)
    return pl.pallas_call(
        _mv_kernel,
        grid=(r // blk,),
        in_specs=[
            pl.BlockSpec((blk, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(a, v)
