"""Layer-1 Pallas kernel: row L2 normalization (paper Alg. 4.1 step 5, Z -> Y).

Trivially parallel over row blocks; zero rows (padding, or isolated vertices
whose embedding vanished) are passed through as zeros instead of NaN.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 128
DIM = 16
BLK = 64


def _normalize_kernel(z_ref, o_ref):
    z = z_ref[...]
    norm = jnp.sqrt(jnp.sum(z * z, axis=1, keepdims=True))
    o_ref[...] = z / jnp.where(norm == 0.0, 1.0, norm)


@functools.partial(jax.jit, static_argnames=("blk",))
def normalize_rows(z, *, blk=BLK):
    """Y[i] = Z[i] / ||Z[i]||; zero rows stay zero. z (R, D), R % blk == 0."""
    r, d = z.shape
    assert r % blk == 0, (r, blk)
    return pl.pallas_call(
        _normalize_kernel,
        grid=(r // blk,),
        in_specs=[pl.BlockSpec((blk, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), jnp.float32),
        interpret=True,
    )(z)
