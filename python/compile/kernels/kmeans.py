"""Layer-1 Pallas kernel: k-means assign + partial sums (paper §4.3.3 map side).

One MR map task's compute over a tile of points: the point-center distance
matrix uses the same MXU-matmul identity as the RBF kernel
(||p||^2 + ||c||^2 - 2 P C^T), then argmin for the assignment and a masked
one-hot contraction for the combiner-side partial sums — exactly what the
paper's map + combiner emit to the reducer (per-center coordinate sums and
counts).

Accumulation across point blocks uses the standard sequential-grid pattern:
outputs are zeroed on the first grid step and accumulated on later ones.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Geometry baked into the AOT artifact.
PTS = 256  # points per tile
DIM = 16  # feature dim (embedding k padded up)
K = 16  # centers (clusters padded up)
BLK = 128  # points per grid step


def _kmeans_kernel(p_ref, c_ref, m_ref, assign_ref, sums_ref, counts_ref):
    i = pl.program_id(0)
    p = p_ref[...]  # (BLK, D)
    c = c_ref[...]  # (K, D)
    m = m_ref[...]  # (BLK,)
    pp = jnp.sum(p * p, axis=1, keepdims=True)  # (BLK, 1)
    cc = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, K)
    pc = jnp.dot(p, c.T, preferred_element_type=jnp.float32)  # MXU
    d2 = pp + cc - 2.0 * pc
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    assign_ref[...] = assign
    onehot = (
        assign[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, c.shape[0]), 1)
    ).astype(jnp.float32) * m[:, None]

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    sums_ref[...] += jnp.dot(onehot.T, p, preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("blk",))
def kmeans_step(points, centers, mask, *, blk=BLK):
    """Assign each point to its nearest center; masked partial sums/counts.

    points (P, D), centers (K, D), mask (P,) in {0,1}.
    Returns (assign (P,) i32, sums (K, D) f32, counts (K,) f32).
    """
    p, d = points.shape
    k, _ = centers.shape
    assert mask.shape == (p,) and p % blk == 0, (points.shape, mask.shape, blk)
    return pl.pallas_call(
        _kmeans_kernel,
        grid=(p // blk,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.int32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(points, centers, mask)
