"""Layer-1 Pallas kernel: tiled RBF similarity block (paper Alg. 4.2 hot spot).

TPU mapping of the paper's per-pair ``computeSimilarity``: instead of a scalar
loop over pairs, a whole (P, Q) tile of similarities is produced at once using
the matmul identity

    ||x_i - y_j||^2 = ||x_i||^2 + ||y_j||^2 - 2 x_i . y_j

so the dominant term is a single (BLK, D) x (D, BLK) contraction that lands on
the MXU systolic array. BlockSpec tiles the (P, Q) output into BLK x BLK
pieces; each grid step streams one x row-block and one y row-block HBM->VMEM
(BLK*D + BLK*D + BLK*BLK floats — ~80 KiB at BLK=128, D=16 — comfortably
double-bufferable in ~16 MiB VMEM).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic custom-calls;
the same HLO the interpreter lowers to is what the Rust runtime executes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile geometry baked into the AOT artifact (see aot.py). The Rust
# runtime pads inputs up to these shapes (runtime/executor.rs).
TILE = 128
DIM = 16
BLK = 64  # sub-block each grid step computes


def _rbf_kernel(x_ref, y_ref, g_ref, o_ref):
    x = x_ref[...]  # (BLK, D)
    y = y_ref[...]  # (BLK, D)
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (BLK, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, BLK)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)  # clamp fp cancellation
    o_ref[...] = jnp.exp(-g_ref[0, 0] * d2)


@functools.partial(jax.jit, static_argnames=("blk",))
def rbf_block(x, y, gamma, *, blk=BLK):
    """S = exp(-gamma ||x_i - y_j||^2) for one tile pair.

    x (P, D), y (Q, D), gamma scalar; P and Q must be multiples of ``blk``.
    """
    p, d = x.shape
    q, _ = y.shape
    assert p % blk == 0 and q % blk == 0, (p, q, blk)
    g = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _rbf_kernel,
        grid=(p // blk, q // blk),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i, j: (i, 0)),
            pl.BlockSpec((blk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, blk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, q), jnp.float32),
        interpret=True,
    )(x, y, g)
