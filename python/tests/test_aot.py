"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import os
import tempfile

import jax

from compile import aot, model


def test_to_hlo_text_produces_hlo_module():
    fn, specs = model.ENTRY_POINTS["matvec_block"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # return_tuple=True: root is a tuple (the rust side unwraps it).
    assert "tuple" in text.lower()


def test_compile_all_writes_everything_and_is_idempotent():
    with tempfile.TemporaryDirectory() as d:
        written = aot.compile_all(d)
        assert set(written) == set(model.ENTRY_POINTS)
        for name in model.ENTRY_POINTS:
            path = os.path.join(d, f"{name}.hlo.txt")
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 100, name
        manifest = open(os.path.join(d, "manifest.txt")).read()
        lines = [l for l in manifest.strip().splitlines() if l]
        assert len(lines) == len(model.ENTRY_POINTS)
        for line in lines:
            name, ins, arity = line.split("|")
            assert name in model.ENTRY_POINTS
            assert int(arity) >= 1
            assert all("[" in s and s.endswith("]") for s in ins.split(";"))
        # Second run with fresh artifacts: nothing rewritten.
        assert aot.compile_all(d) == []


def test_manifest_matches_entry_point_arity():
    with tempfile.TemporaryDirectory() as d:
        aot.compile_all(d)
        manifest = open(os.path.join(d, "manifest.txt")).read()
        arities = {
            line.split("|")[0]: int(line.split("|")[2])
            for line in manifest.strip().splitlines()
        }
        assert arities["kmeans_step"] == 3
        assert arities["similarity_degree_block"] == 2
        assert arities["rbf_block"] == 1
