"""Pallas mat-vec kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matvec import matvec_block


def _rand(shape, seed, scale=1.0):
    return (np.random.default_rng(seed).normal(size=shape) * scale).astype(
        np.float32
    )


@settings(max_examples=20, deadline=None)
@given(
    r_blocks=st.integers(1, 4),
    c=st.integers(1, 300),
    seed=st.integers(0, 2**31),
)
def test_matvec_matches_ref_across_shapes(r_blocks, c, seed):
    blk = 16
    r = r_blocks * blk
    a = _rand((r, c), seed)
    v = _rand((c,), seed + 1)
    got = matvec_block(jnp.asarray(a), jnp.asarray(v), blk=blk)
    want = ref.matvec_block_ref(jnp.asarray(a), jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-4)


def test_matvec_aot_tile_shape():
    a = _rand((256, 256), 0)
    v = _rand((256,), 1)
    got = matvec_block(jnp.asarray(a), jnp.asarray(v))
    want = a @ v
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=1e-4)


def test_matvec_identity():
    n = 128
    eye = np.eye(n, dtype=np.float32)
    v = _rand((n,), 7)
    got = np.asarray(matvec_block(jnp.asarray(eye), jnp.asarray(v)))
    np.testing.assert_allclose(got, v, atol=1e-6)


def test_matvec_zero_matrix():
    a = jnp.zeros((128, 64))
    v = jnp.ones((64,))
    assert np.abs(np.asarray(matvec_block(a, v))).max() == 0.0
