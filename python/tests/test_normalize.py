"""Pallas row-normalization kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.normalize import normalize_rows


@settings(max_examples=20, deadline=None)
@given(
    r_blocks=st.integers(1, 4),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_normalize_matches_ref(r_blocks, d, seed):
    blk = 16
    r = r_blocks * blk
    z = np.random.default_rng(seed).normal(size=(r, d)).astype(np.float32)
    z[:: max(r // 4, 1)] = 0.0  # sprinkle zero rows
    got = normalize_rows(jnp.asarray(z), blk=blk)
    want = ref.normalize_rows_ref(jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_normalize_aot_tile_shape():
    z = np.random.default_rng(0).normal(size=(128, 16)).astype(np.float32)
    got = np.asarray(normalize_rows(jnp.asarray(z)))
    norms = np.linalg.norm(got, axis=1)
    np.testing.assert_allclose(norms, np.ones(128), atol=1e-6)


def test_normalize_zero_rows_stay_zero_not_nan():
    z = jnp.zeros((64, 8))
    got = np.asarray(normalize_rows(z, blk=64))
    assert not np.isnan(got).any()
    assert np.abs(got).max() == 0.0
