"""Pallas RBF kernel vs the pure-jnp oracle (hypothesis shape/value sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rbf import rbf_block


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(
    p_blocks=st.integers(1, 3),
    q_blocks=st.integers(1, 3),
    d=st.integers(1, 16),
    gamma=st.floats(0.01, 4.0),
    seed=st.integers(0, 2**31),
)
def test_rbf_matches_ref_across_shapes(p_blocks, q_blocks, d, gamma, seed):
    blk = 8  # small sub-block: the grid logic is what's under test
    p, q = p_blocks * blk, q_blocks * blk
    x = _rand((p, d), seed)
    y = _rand((q, d), seed + 1)
    got = rbf_block(jnp.asarray(x), jnp.asarray(y), gamma, blk=blk)
    want = ref.rbf_block_ref(jnp.asarray(x), jnp.asarray(y), gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rbf_aot_tile_shape():
    # The exact geometry aot.py freezes (128x16, blk 64).
    x = _rand((128, 16), 0)
    y = _rand((128, 16), 1)
    got = rbf_block(jnp.asarray(x), jnp.asarray(y), 0.5)
    want = ref.rbf_block_ref(jnp.asarray(x), jnp.asarray(y), 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rbf_self_similarity_is_one():
    x = _rand((64, 8), 3)
    s = np.asarray(rbf_block(jnp.asarray(x), jnp.asarray(x), 1.0, blk=64))
    # atol 1e-5: the matmul identity ||x||²+||y||²−2x·y cancels to ~1e-6
    # in f32 at distance 0 (this is why the Rust side keeps the diagonal
    # unconditionally rather than trusting exp(-gamma*d2) == 1).
    np.testing.assert_allclose(np.diag(s), np.ones(64), atol=1e-5)
    # Symmetry of the self-tile.
    np.testing.assert_allclose(s, s.T, atol=1e-6)


def test_rbf_values_in_unit_interval():
    x = _rand((64, 4), 5) * 10
    s = np.asarray(rbf_block(jnp.asarray(x), jnp.asarray(x), 2.0, blk=32))
    assert (s >= 0).all() and (s <= 1 + 1e-6).all()


def test_rbf_rejects_unaligned_rows():
    x = jnp.zeros((100, 4))  # 100 % 64 != 0
    with pytest.raises(AssertionError):
        rbf_block(x, x, 1.0)
