"""Pallas k-means step kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kmeans import kmeans_step


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@settings(max_examples=20, deadline=None)
@given(
    p_blocks=st.integers(1, 3),
    k=st.integers(1, 16),
    d=st.integers(1, 16),
    mask_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
def test_kmeans_matches_ref_across_shapes(p_blocks, k, d, mask_frac, seed):
    blk = 32
    p = p_blocks * blk
    rng = np.random.default_rng(seed)
    points = rng.normal(size=(p, d)).astype(np.float32)
    centers = rng.normal(size=(k, d)).astype(np.float32) * 2
    mask = (rng.random(p) < mask_frac).astype(np.float32)
    got_a, got_s, got_c = kmeans_step(
        jnp.asarray(points), jnp.asarray(centers), jnp.asarray(mask), blk=blk
    )
    want_a, want_s, want_c = ref.kmeans_step_ref(
        jnp.asarray(points), jnp.asarray(centers), jnp.asarray(mask)
    )
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c), atol=1e-6)


def test_kmeans_aot_tile_shape():
    points = _rand((256, 16), 0)
    centers = _rand((16, 16), 1)
    mask = np.ones(256, dtype=np.float32)
    a, s, c = kmeans_step(
        jnp.asarray(points), jnp.asarray(centers), jnp.asarray(mask)
    )
    ra, rs, rc = ref.kmeans_step_ref(
        jnp.asarray(points), jnp.asarray(centers), jnp.asarray(mask)
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ra))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), atol=1e-4)
    np.testing.assert_allclose(np.asarray(c), np.asarray(rc), atol=1e-6)


def test_kmeans_counts_conserve_mask():
    points = _rand((64, 4), 2)
    centers = _rand((4, 4), 3)
    mask = np.zeros(64, dtype=np.float32)
    mask[:40] = 1.0
    _, _, counts = kmeans_step(
        jnp.asarray(points), jnp.asarray(centers), jnp.asarray(mask), blk=32
    )
    assert float(np.asarray(counts).sum()) == 40.0


def test_kmeans_obvious_assignment():
    points = jnp.asarray([[0.0, 0.0], [10.0, 10.0]] * 16, dtype=jnp.float32)
    centers = jnp.asarray([[0.0, 0.0], [10.0, 10.0]], dtype=jnp.float32)
    mask = jnp.ones(32)
    a, s, c = kmeans_step(points, centers, mask, blk=32)
    np.testing.assert_array_equal(np.asarray(a), np.tile([0, 1], 16))
    np.testing.assert_allclose(np.asarray(c), [16.0, 16.0])
