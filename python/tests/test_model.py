"""Layer-2 model entry points: shapes, composition, oracle agreement."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


def test_entry_points_cover_expected_set():
    assert set(model.ENTRY_POINTS) == {
        "rbf_block",
        "similarity_degree_block",
        "matvec_block",
        "laplacian_block",
        "kmeans_step",
        "normalize_rows",
        "degree_rowsum",
    }


def test_every_entry_point_traces_at_declared_shapes():
    # jax.eval_shape runs the tracer without compute: catches shape bugs.
    for name, (fn, specs) in model.ENTRY_POINTS.items():
        out = jax.eval_shape(fn, *specs)
        assert out is not None, name


def test_similarity_degree_block_consistent():
    x = _rand((128, 16), 0)
    y = _rand((128, 16), 1)
    s, d = model.similarity_degree_block(jnp.asarray(x), jnp.asarray(y), 0.7)
    s_ref = ref.rbf_block_ref(jnp.asarray(x), jnp.asarray(y), 0.7)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(d), np.asarray(s).sum(axis=1), rtol=1e-5
    )


def test_laplacian_block_matches_ref():
    s = _rand((256, 256), 2) ** 2  # nonnegative similarities
    dinv_r = np.abs(_rand((256,), 3)) + 0.1
    dinv_c = np.abs(_rand((256,), 4)) + 0.1
    for flag in (0.0, 1.0):
        got = model.laplacian_block(
            jnp.asarray(s), jnp.asarray(dinv_r), jnp.asarray(dinv_c), flag
        )
        want = ref.laplacian_block_ref(
            jnp.asarray(s), jnp.asarray(dinv_r), jnp.asarray(dinv_c), flag
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_degree_rowsum_matches():
    s = _rand((128, 128), 5) ** 2
    got = model.degree_rowsum(jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(got), s.sum(axis=1), rtol=1e-5)


def test_pipeline_composition_small():
    """Mini spectral pipeline composed purely from L2 entry points."""
    rng = np.random.default_rng(9)
    # Two separated blobs, 64 points each, padded to the tile geometry.
    a = rng.normal(size=(64, 16)).astype(np.float32) * 0.2
    b = rng.normal(size=(64, 16)).astype(np.float32) * 0.2 + 5.0
    x = np.vstack([a, b])
    s = np.asarray(model.similarity_block(jnp.asarray(x), jnp.asarray(x), 0.5))
    d = s.sum(axis=1)
    dinv = 1.0 / np.sqrt(d)
    # Dense L via numpy (the L2 laplacian_block is tile-shaped 256x256).
    lap = np.eye(128, dtype=np.float32) - dinv[:, None] * s * dinv[None, :]
    vals, vecs = np.linalg.eigh(lap.astype(np.float64))
    z = vecs[:, :2].astype(np.float32)
    z = np.pad(z, ((0, 0), (0, 14)))
    y = np.asarray(model.normalize_rows(jnp.asarray(z)))
    # Disconnected blobs -> nullspace indicator structure: after row
    # normalization each blob collapses near one unit vector, and the two
    # vectors are (near-)orthogonal, so the blob means sit ~sqrt(2) apart.
    gap = np.linalg.norm(y[:64].mean(axis=0) - y[64:].mean(axis=0))
    within = max(y[:64].std(axis=0).max(), y[64:].std(axis=0).max())
    assert gap > 1.0, f"blob means too close: {gap}"
    assert within < 0.2, f"blobs not collapsed: {within}"
